"""Streaming-admission invariance properties (the tentpole contract):
for random request mixes, arrival orders, slot widths, and forced
preemption/park/restore cycles, the streaming engine's final fp32
densities are BITWISE-equal to standalone fea/hybrid.run_hybrid runs —
and live admission never recompiles the batched step."""
import dataclasses
import random
import time

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common import materialize
from repro.configs.cronet import get_cronet_config
from repro.core import cronet
from repro.fea import fea2d, hybrid
from repro.serve.topo_service import TopoRequest, TopoServingEngine

U_SCALE = 50.0
_CTX = {}


def _ctx():
    """Module-cached (cfg, params, problem pool) — property examples must
    share one config so compiled steps are reused across examples."""
    if not _CTX:
        cfg = dataclasses.replace(get_cronet_config("small"),
                                  nelx=12, nely=4, hist_len=3)
        params = materialize(cronet.param_specs(
            dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
        pool = [fea2d.point_load_problem(
            cfg.nelx, cfg.nely, load_node=(i % (cfg.nelx - 1), 0),
            load=(0.0, -1.0 - 0.1 * i)) for i in range(8)]
        _CTX.update(cfg=cfg, params=params, pool=pool, refs={})
    return _CTX["cfg"], _CTX["params"], _CTX["pool"]


def _ref_density(prob_idx: int, n_iter: int) -> np.ndarray:
    """Standalone run_hybrid reference, memoized across property examples."""
    cfg, params, pool = _ctx()
    key = (prob_idx, n_iter)
    if key not in _CTX["refs"]:
        res = hybrid.run_hybrid(cfg, params, u_scale=U_SCALE, n_iter=n_iter,
                                precision="fp32", problem=pool[prob_idx],
                                compute_metrics=False)
        _CTX["refs"][key] = res.density
    return _CTX["refs"][key]


# ------------------------------------------------- the invariance property


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 4),       # slot width
       st.integers(3, 7),       # request count
       st.integers(0, 10 ** 6))  # mix/arrival-order seed
def test_streaming_densities_bitwise_equal_standalone(slots, n_req, seed):
    """Any request mix served through live submission (random problems,
    iteration budgets, deadline mixes, arrival order) must reproduce each
    standalone run bitwise — scheduling buys deadlines, not approximation."""
    cfg, params, pool = _ctx()
    rng = random.Random(seed)
    picks = [(rng.randrange(len(pool)), rng.randint(3, 7))
             for _ in range(n_req)]
    deadlines = [rng.choice([None, 30.0, 120.0]) for _ in range(n_req)]
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=slots,
                            precision="fp32")
    futs = []
    for k, ((pi, ni), dl) in enumerate(zip(picks, deadlines)):
        futs.append(eng.submit(
            TopoRequest(uid=k, problem=pool[pi], n_iter=ni), deadline_s=dl))
        if rng.random() < 0.3:   # stagger some arrivals mid-serve
            time.sleep(0.01)
    reqs = [f.result(timeout=300) for f in futs]
    eng.shutdown()
    for req, (pi, ni) in zip(reqs, picks):
        assert req.done and req.fea_iters + req.cronet_iters == ni
        np.testing.assert_array_equal(
            req.density, _ref_density(pi, ni),
            err_msg=f"uid {req.uid} (problem {pi}, {ni} iters)")


# ---------------------------------------- preemption / park-restore cycles


@settings(max_examples=4, deadline=None)
@given(st.integers(8, 14),      # occupant budget (long, deadline-less)
       st.integers(2, 5),       # urgent budget (short, tight deadline)
       st.integers(0, 10 ** 6))
def test_preemption_park_restore_is_bitwise_exact(long_n, short_n, seed):
    """Force an eviction: fill both lanes with deadline-less long jobs,
    then submit a short job whose deadline is only feasible via
    preemption (tick_time_s pinned so the decision is deterministic).
    The evicted lane is parked, re-admitted, and must still finish
    bitwise-identical to its standalone run."""
    cfg, params, pool = _ctx()
    rng = random.Random(seed)
    occ = [(rng.randrange(len(pool)), long_n) for _ in range(2)]
    urg = (rng.randrange(len(pool)), short_n)
    # tick_time_s=10 makes "waiting" always look like a miss while the
    # deadline below stays feasible for an immediate slot -> the scheduler
    # MUST preempt (victims are deadline-less, hence provably safe)
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                            precision="fp32", tick_time_s=10.0)
    futs = [eng.submit(TopoRequest(uid=k, problem=pool[pi], n_iter=ni))
            for k, (pi, ni) in enumerate(occ)]
    # wait until both occupants are actually admitted (lanes full)
    t0 = time.time()
    while any(a is None for a in eng._shards[0].slot_adm):
        assert time.time() - t0 < 60, "occupants never admitted"
        time.sleep(0.005)
    fut_u = eng.submit(TopoRequest(uid=9, problem=pool[urg[0]],
                                   n_iter=urg[1]),
                       deadline_s=urg[1] * 10.0 + 5.0)
    reqs = [f.result(timeout=600) for f in futs]
    req_u = fut_u.result(timeout=600)
    eng.shutdown()
    assert eng.preemptions >= 1, "preemption never fired"
    assert sum(r.preemptions for r in reqs) >= 1, "no occupant was parked"
    for req, (pi, ni) in zip(reqs + [req_u], occ + [urg]):
        np.testing.assert_array_equal(
            req.density, _ref_density(pi, ni),
            err_msg=f"uid {req.uid} (problem {pi}, {ni} iters, "
                    f"{req.preemptions} preemptions)")


# ----------------------------------------------- no-recompilation contract


def test_live_admission_is_a_compiled_cache_hit():
    """submit() against a running tick loop must never retrace the
    batched step: the engine's trace counter stays flat from the first
    warm batch through arbitrarily many live admissions."""
    cfg, params, pool = _ctx()
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                            precision="fp32")
    # warm: compiles the width-2 step once (count may also be 0 if an
    # earlier test already compiled this config)
    eng.run([TopoRequest(uid=100 + i, problem=pool[i], n_iter=3)
             for i in range(2)])
    traces_warm = eng.step.trace_count[0]
    # live phase: keep the loop busy with a long occupant, then stream
    # admissions against the running engine
    long_fut = eng.submit(TopoRequest(uid=0, problem=pool[0], n_iter=30))
    futs = []
    for k in range(5):
        assert eng.running
        futs.append(eng.submit(
            TopoRequest(uid=1 + k, problem=pool[(k + 1) % len(pool)],
                        n_iter=4)))
        time.sleep(0.02)
    for f in futs + [long_fut]:
        f.result(timeout=300)
    assert eng.drain(timeout=60)
    assert eng.step.trace_count[0] == traces_warm, \
        "live admission retraced the compiled step"
    eng.shutdown()
    # every admission actually went through the running loop
    assert all(f.result().done for f in futs)


# ------------------------------------------------- width-ladder contracts


def test_ladder_rung_serving_bitwise_equals_dedicated_width():
    """The tentpole ladder contract: a request served on a ladder engine
    at rung W is bitwise-equal to the same request on a DEDICATED
    fixed-width-W engine (and hence to its standalone run) — the rung
    choice is a latency decision, never a numerics decision. Also pins
    the compile bound: serving across every rung retraces at most
    ``len(rungs)`` times."""
    cfg, params, pool = _ctx()
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=4,
                            precision="fp32", ladder=(2, 4))
    assert eng.rungs == (2, 4)
    traces0 = eng.step.trace_count[0]

    # occupancy 2 -> rung 2
    futs = [eng.submit(TopoRequest(uid=k, problem=pool[k], n_iter=4))
            for k in range(2)]
    narrow = [f.result(timeout=300) for f in futs]
    assert eng.drain(timeout=60)
    # occupancy 4 -> rung 4
    futs = [eng.submit(TopoRequest(uid=10 + k, problem=pool[k], n_iter=5))
            for k in range(4)]
    wide = [f.result(timeout=300) for f in futs]
    assert eng.step.trace_count[0] - traces0 <= len(eng.rungs), \
        "ladder serving retraced beyond the precompiled rungs"
    stats = eng.throughput_stats()
    eng.shutdown()
    assert stats["ladder"]["rungs"] == [2, 4]
    assert stats["ladder"]["rung_steps"]["2"] > 0
    assert stats["ladder"]["rung_steps"]["4"] > 0

    # dedicated fixed-width engines serving the SAME requests
    ded2 = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                             precision="fp32")
    ref2 = ded2.run([TopoRequest(uid=k, problem=pool[k], n_iter=4)
                     for k in range(2)])
    ded2.shutdown()
    ded4 = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=4,
                             precision="fp32")
    ref4 = ded4.run([TopoRequest(uid=10 + k, problem=pool[k], n_iter=5)
                     for k in range(4)])
    ded4.shutdown()
    for got, want in zip(narrow + wide, ref2 + ref4):
        np.testing.assert_array_equal(got.density, want.density,
                                      err_msg=f"uid {got.uid}")
        np.testing.assert_array_equal(
            got.density,
            _ref_density(got.uid % 10, got.fea_iters + got.cronet_iters),
            err_msg=f"uid {got.uid} vs standalone")


def test_midstream_rung_change_drops_nothing():
    """A rung change mid-serve (grow on a burst, shrink with a live lane
    compaction once the burst drains) must not drop, restart, or perturb
    any in-flight request: every density stays bitwise-equal to its
    standalone run and iteration counts are exact."""
    cfg, params, pool = _ctx()
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=4,
                            precision="fp32", ladder=(2, 4))
    # long occupant admits alone at rung 2 (lane 0)
    f_long = eng.submit(TopoRequest(uid=0, problem=pool[0], n_iter=20))
    t0 = time.time()
    while eng._shards[0].slot_adm[0] is None:
        assert time.time() - t0 < 60, "occupant never admitted"
        time.sleep(0.005)
    # burst fills lanes 1..3 -> grow to rung 4; the two short jobs finish
    # first, leaving lanes 0 and 3 live -> shrink migrates lane 3 down
    futs = [eng.submit(TopoRequest(uid=1 + k, problem=pool[1 + k],
                                   n_iter=n))
            for k, n in enumerate((3, 3, 8))]
    reqs = [f.result(timeout=600) for f in futs] + [f_long.result(600)]
    assert eng.drain(timeout=60)
    stats = eng.throughput_stats()
    eng.shutdown()
    assert stats["ladder"]["rung_changes"] >= 2, stats["ladder"]
    # the 8-iter job outlives the shorts in a lane >= the shrunk width,
    # so the shrink must have compacted it down LIVE (exact lane move)
    assert stats["ladder"]["migrations"] >= 1, stats["ladder"]
    for req, (pi, ni) in zip(reqs, [(1, 3), (2, 3), (3, 8), (0, 20)]):
        assert req.done and req.fea_iters + req.cronet_iters == ni
        np.testing.assert_array_equal(
            req.density, _ref_density(pi, ni),
            err_msg=f"uid {req.uid} (problem {pi}, {ni} iters)")


# ------------------------------------- deadline stats + future semantics


def test_deadline_stats_and_future_timeout():
    cfg, params, pool = _ctx()
    eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                            precision="fp32")
    fut = eng.submit(TopoRequest(uid=0, problem=pool[0], n_iter=4),
                     deadline_s=300.0)
    with pytest.raises(TimeoutError):
        TopoFuture_never = eng.submit(
            TopoRequest(uid=1, problem=pool[1], n_iter=25))
        TopoFuture_never.result(timeout=0.0)
    req = fut.result(timeout=300)
    assert req.deadline_met is True
    eng.drain()
    eng.stop()
    stats = eng.throughput_stats()
    assert stats["deadline_hit_rate"] == 1.0
    assert stats["p99_latency_s"] >= stats["p50_latency_s"] > 0.0
    # deadline-less request carries no verdict
    assert TopoFuture_never.result().deadline_met is None
    # submit after stop() restarts the tick loops (documented behaviour
    # the run() shim depends on); shutdown() below is terminal
    assert not eng.running
    restarted = eng.submit(TopoRequest(uid=2, problem=pool[0], n_iter=2))
    assert restarted.result(timeout=300).done and eng.running
    eng.shutdown()
    # mesh mismatch fails at submit time, in the caller's thread
    eng2 = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                             precision="fp32")
    with pytest.raises(ValueError, match="mesh"):
        eng2.submit(TopoRequest(uid=3,
                                problem=fea2d.point_load_problem(8, 4),
                                n_iter=2))
    eng2.shutdown()
