"""CRONet reproduction tests: paper Table I exact numbers, fusion-path
equivalence (megakernel == layerwise == reference), decoder shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import materialize, param_count
from repro.configs.cronet import SIZES, get_cronet_config
from repro.core import cronet, fusion


def test_param_count_matches_paper():
    cfg = get_cronet_config("medium")
    assert cfg.param_count() == 419760          # paper: "419K parameters"
    assert param_count(cronet.param_specs(cfg)) == 419760
    # constant across sizes (paper §VI-B)
    for c in SIZES.values():
        assert c.param_count() == 419760


def test_per_layer_params_match_table1():
    cfg = get_cronet_config("medium")
    specs = cronet.param_specs(cfg)
    t, b = specs["trunk"], specs["branch"]
    sz = lambda s: int(np.prod(s.shape))
    assert sz(t["conv1"]) == 288          # Table I: 288
    assert sz(t["conv2"]) == 9216         # Table I: 9K
    assert sz(t["fc1"]) == 192000         # Table I: 192K
    assert sz(t["fc2"]) == 102400         # Table I: 102K
    assert sz(b["conv1"]) == 144          # Table I: 144
    assert sz(b["conv2"]) == 4608         # Table I: 4.6K
    assert sz(b["rnn_wx"]) + sz(b["rnn_wh"]) == 6144   # Table I: 6.1K
    assert sz(b["fc1"]) == 2560           # Table I: 2.5K
    assert sz(b["fc2"]) == 102400         # Table I: 102K


@pytest.mark.parametrize("size,total_macs", [("small", 27.6e6),
                                             ("medium", 53.5e6),
                                             ("large", 105.8e6)])
def test_macs_match_table1(size, total_macs):
    macs = cronet.count_macs(get_cronet_config(size))
    assert abs(macs["total"] - total_macs) / total_macs < 0.01, macs["total"]


def test_fusion_paths_equivalent():
    cfg = dataclasses.replace(get_cronet_config("small"), dtype="float32")
    params = materialize(cronet.param_specs(cfg), jax.random.key(1))
    lv = jax.random.normal(jax.random.key(2),
                           (4, cfg.nely + 1, cfg.nelx + 1, 1), jnp.float32) * 0.3
    hist = jax.random.uniform(jax.random.key(3),
                              (cfg.hist_len, cfg.nely, cfg.nelx, 1))
    ref = cronet.forward(cfg, params, lv[None], hist[None])[0]
    for fc in [fusion.FusionConfig(True, True, True),
               fusion.FusionConfig(True, False, False),
               fusion.FusionConfig(False, False, False)]:
        out = fusion.infer(cfg, params, lv, hist, fc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"fusion path {fc.path}")


def test_megakernel_bf16():
    cfg = get_cronet_config("small")   # bf16 default (deployment precision)
    params = materialize(cronet.param_specs(cfg), jax.random.key(1))
    lv = (jax.random.normal(jax.random.key(2),
                            (4, cfg.nely + 1, cfg.nelx + 1, 1)) * 0.3
          ).astype(jnp.bfloat16)
    hist = jax.random.uniform(jax.random.key(3),
                              (cfg.hist_len, cfg.nely, cfg.nelx, 1)
                              ).astype(jnp.bfloat16)
    ref = cronet.forward(cfg, params, lv[None], hist[None])[0]
    out = fusion.infer(cfg, params, lv, hist)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.1, atol=0.1)


def test_decode_displacement_shapes():
    for size, c in SIZES.items():
        u = jnp.zeros((2, c.p))
        grid = cronet.decode_displacement(c, u)
        assert grid.shape == (2, c.nely + 1, c.nelx + 1, 2)


def test_trunk_branch_independence():
    """BranchNet/TrunkNet share no inputs until the Mul — the property the
    paper exploits for concurrent execution (§IV-A)."""
    cfg = dataclasses.replace(get_cronet_config("small"), dtype="float32")
    params = materialize(cronet.param_specs(cfg), jax.random.key(1))
    lv = jnp.ones((1, 4, cfg.nely + 1, cfg.nelx + 1, 1))
    h1 = jnp.zeros((1, cfg.hist_len, cfg.nely, cfg.nelx, 1))
    h2 = jnp.ones((1, cfg.hist_len, cfg.nely, cfg.nelx, 1))
    t1 = cronet.trunk_forward(cfg, params["trunk"], lv)
    t2 = cronet.trunk_forward(cfg, params["trunk"], lv)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    b1 = cronet.branch_forward(cfg, params["branch"], h1)
    b2 = cronet.branch_forward(cfg, params["branch"], h2)
    assert not np.allclose(np.asarray(b1), np.asarray(b2))
