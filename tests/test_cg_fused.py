"""Fused batched-CG backend contracts (kernels/cg_fused.py).

The whole suite pins ONE invariant from three directions: the fused
solve (``fea2d.solve_b(..., backend="fused")`` — the entire Jacobi-PCG
loop inside a single pallas_call) is a pure deployment knob. Densities,
displacements, and per-slot iteration counts are BITWISE-equal to the
reference XLA path across batch widths, warm starts, ``need`` masks,
and shape-class ``elem_mask`` padding; the serving engine on the fused
backend keeps the no-recompilation streaming contract; and every
kernel entry point resolves ``interpret=None`` by platform
auto-detection instead of hardwiring the interpreter.

Widths start at 2: the reference's bitwise slot-invariance only holds
for batch >= 2 (unit batch dims lower through different
vectorization), so the fused contract is defined on the same domain.

The sweeps compare UNDER JIT — the contract's domain (see the
cg_fused.py module docstring): the serving tick always runs jitted,
and two standalone eager programs are not bitwise-stable on CPU XLA
even reference-vs-reference (different FMA-contraction choices in the
``_ke_apply`` stencil chain).
"""
import dataclasses
import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import materialize
from repro.configs.cronet import get_cronet_config
from repro.core import cronet
from repro.fea import fea2d
from repro.kernels import resolve_interpret
from repro.serve.topo_service import TopoRequest, TopoServingEngine

U_SCALE = 50.0


def _probs(n, nelx=12, nely=4):
    return [fea2d.point_load_problem(
        nelx, nely, load_node=(i % (nelx - 1), 0),
        load=(0.05 * i, -1.0 - 0.1 * i)) for i in range(n)]


def _solve_both(bp, X, U0=None, need=None):
    # jitted with (bp, X, ...) as traced arguments — the same calling
    # convention as the engine's compiled tick, the contract's domain
    ref = jax.jit(lambda b, x, u, n: fea2d.solve_b(b, x, U0=u, need=n))(
        bp, X, U0, need)
    fus = jax.jit(lambda b, x, u, n: fea2d.solve_b(b, x, U0=u, need=n,
                                                   backend="fused"))(
        bp, X, U0, need)
    return ref, fus


def _assert_bitwise(ref, fus, msg):
    (ur, ir), (uf, if_) = ref, fus
    np.testing.assert_array_equal(np.asarray(ur), np.asarray(uf),
                                  err_msg=f"{msg}: U diverged")
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(if_),
                                  err_msg=f"{msg}: iteration counts diverged")


# --------------------------------------------------- bitwise equivalence


@pytest.mark.parametrize("width", [2, 3, 4])
def test_fused_bitwise_across_widths(width):
    """Cold-start solves at several batch widths: fused == reference
    bitwise, including identical per-slot iteration counts."""
    bp = fea2d.stack_problems(_probs(width))
    X = jnp.stack([jnp.full((4, 12), 0.3 + 0.1 * i) for i in range(width)])
    _assert_bitwise(*_solve_both(bp, X), msg=f"width {width}")


def test_fused_bitwise_warm_start_and_need_mask():
    """Warm starts (U0 from a truncated solve) and partial ``need``
    masks — the serving tick's actual calling convention — stay
    bitwise. Slots with need=False must come back untouched."""
    bp = fea2d.stack_problems(_probs(3))
    X = jnp.stack([jnp.full((4, 12), 0.5)] * 3)
    U0, _ = fea2d.solve_b(bp, X, max_iter=5)          # stale warm start
    need = jnp.asarray([True, False, True])
    ref, fus = _solve_both(bp, X, U0=U0, need=need)
    _assert_bitwise(ref, fus, msg="warm start + need mask")
    # the frozen slot keeps its warm start and burns zero iterations
    np.testing.assert_array_equal(np.asarray(ref[0][1]),
                                  np.asarray(U0 * bp.free_mask)[1])
    assert int(ref[1][1]) == int(fus[1][1]) == 0


def test_fused_bitwise_under_elem_mask_padding():
    """Shape-class padded problems (passive border, elem_mask) solve
    bitwise-identically on the fused backend."""
    raw = [fea2d.point_load_problem(10, 4, load_node=(3 + i, 0),
                                    load=(0.0, -1.0 - 0.2 * i))
           for i in range(2)]
    bp = fea2d.stack_problems([fea2d.pad_problem(p, 12, 6) for p in raw])
    X = bp.elem_mask * 0.5
    _assert_bitwise(*_solve_both(bp, X), msg="elem_mask padding")


# ------------------------------------------ zero-load stall (regression)


def test_zero_load_slot_with_stale_warm_start_converges_immediately():
    """Regression: a slot with f == 0 (empty serving lane) but a nonzero
    stale warm start used to burn max_iter iterations — the residual
    R = -K U0 is nonzero while the tolerance tol * ||F|| is exactly
    zero, so ``rnorm > tol * fnorm`` never went false. The fnorm > 0
    convergence term makes such slots converged by definition, on BOTH
    backends."""
    live = _probs(1)[0]
    idle = live._replace(f=jnp.zeros_like(live.f))     # load-free lane
    bp = fea2d.stack_problems([live, idle])
    X = jnp.stack([jnp.full((4, 12), 0.5)] * 2)
    # stale state from a previous occupant of the lane
    U0 = jnp.stack([jnp.zeros(live.f.shape[0], jnp.float32),
                    jnp.full((live.f.shape[0],), 0.37, jnp.float32)])
    ref, fus = _solve_both(bp, X, U0=U0)
    _assert_bitwise(ref, fus, msg="zero-load slot")
    its = np.asarray(ref[1])
    assert its[1] == 0, f"idle slot burned {its[1]} iterations"
    assert 0 < its[0] < 2000, "live slot failed to converge"


def test_unknown_backend_raises():
    bp = fea2d.stack_problems(_probs(2))
    X = jnp.stack([jnp.full((4, 12), 0.5)] * 2)
    with pytest.raises(ValueError, match="backend"):
        fea2d.solve_b(bp, X, backend="magic")


# ------------------------------------------- interpret auto-detection


def test_resolve_interpret_auto_detects_platform():
    """None -> interpret exactly on CPU hosts; explicit bools win."""
    assert resolve_interpret(None) == (jax.default_backend() == "cpu")
    assert resolve_interpret() == resolve_interpret(None)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_kernel_entry_points_default_to_auto_detection():
    """Regression: kernel entry points used to hardwire interpret=True,
    silently running the Pallas interpreter on accelerator hosts. Every
    public entry's ``interpret`` default must now be None (auto)."""
    from repro.kernels import (cg_fused, conv, cronet_pipeline,
                               flash_attention, gemm, pool, silu, slstm)
    entries = [conv.conv2d, conv.conv3d, gemm.gemm, pool.maxpool2d,
               pool.adaptive_avg_pool2d, pool.adaptive_avg_pool3d,
               silu.silu_lut, silu.silu_exact, slstm.slstm_fused,
               flash_attention.flash_attention,
               flash_attention.flash_attention_causal_gqa,
               cronet_pipeline.cronet_fused, cg_fused.solve_b_fused]
    for fn in entries:
        default = inspect.signature(fn).parameters["interpret"].default
        assert default is None, (
            f"{fn.__module__}.{fn.__name__} hardwires interpret="
            f"{default!r}; must default to None (platform auto-detect)")


# -------------------------------------- serving engine on the fused path


def test_fused_engine_bitwise_and_streaming_cache_hit():
    """End to end: an engine on fea_backend='fused' serves densities
    bitwise-equal to the reference engine, and live admission against
    its running tick loop never retraces the compiled step."""
    cfg = dataclasses.replace(get_cronet_config("small"),
                              nelx=12, nely=4, hist_len=3)
    params = materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
    pool = _probs(4, nelx=cfg.nelx, nely=cfg.nely)
    reqs = [(i % len(pool), 3 + i % 3) for i in range(4)]

    dens = {}
    for fb in ("reference", "fused"):
        eng = TopoServingEngine(cfg, params, u_scale=U_SCALE, slots=2,
                                precision="fp32", fea_backend=fb)
        assert eng.throughput_stats()["fea_backend"] == fb
        # warm the width-2 step, then measure the streaming trace delta
        eng.run([TopoRequest(uid=100 + k, problem=pool[pi], n_iter=ni)
                 for k, (pi, ni) in enumerate(reqs[:2])])
        traces_warm = eng.step.trace_count[0]
        futs = []
        for k, (pi, ni) in enumerate(reqs):
            futs.append(eng.submit(
                TopoRequest(uid=k, problem=pool[pi], n_iter=ni)))
            time.sleep(0.01)
        done = [f.result(timeout=300) for f in futs]
        assert eng.drain(timeout=60)
        assert eng.step.trace_count[0] == traces_warm, \
            f"live admission retraced the {fb} step"
        eng.shutdown()
        dens[fb] = [np.asarray(r.density) for r in done]

    for i, (a, b) in enumerate(zip(dens["reference"], dens["fused"])):
        np.testing.assert_array_equal(
            a, b, err_msg=f"request {i}: fused-engine density diverged")
