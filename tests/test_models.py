"""Per-architecture smoke tests (REQUIRED deliverable f): reduced config of
the same family, one forward + one train step on CPU, output shapes +
no-NaN assertions."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.common import check_finite, materialize, param_count
from repro.configs.all import ASSIGNED
from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.optim import adamw
from repro.train.steps import TrainConfig, make_train_step

B, S = 2, 32


def _setup(name):
    cfg = get_config(name).reduce()
    specs = M.param_specs(cfg)
    params = materialize(specs, jax.random.key(0))
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, B, S).next_batch().items()}
    return cfg, params, batch


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_smoke(name):
    cfg, params, batch = _setup(name)
    lgts, aux = M.forward(cfg, params, batch)
    assert lgts.shape == (B, S, cfg.padded_vocab)
    assert bool(check_finite(lgts))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_smoke(name):
    cfg, params, batch = _setup(name)
    tc = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=10))
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw.init_state(tc.optimizer, params)
    p2, o2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    assert float(metrics["grad_norm"]) > 0
    assert bool(check_finite(p2))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, p2))
    assert moved > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_loss_decreases(name):
    """3 steps on one repeated batch must reduce loss (training sanity)."""
    cfg, params, batch = _setup(name)
    tc = TrainConfig(optimizer=adamw.AdamWConfig(
        lr=5e-3, warmup_steps=0, total_steps=100, weight_decay=0.0))
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw.init_state(tc.optimizer, params)
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_full_configs_param_counts():
    """Full-size configs instantiate ABSTRACTLY (no allocation) and land in
    the right parameter-count ballpark."""
    expected = {
        "qwen2.5-32b": (31e9, 36e9),
        "qwen2-72b": (70e9, 76e9),
        "granite-3-8b": (7e9, 9e9),
        "granite-8b": (7e9, 9e9),
        "recurrentgemma-2b": (2e9, 4e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "xlstm-1.3b": (1.0e9, 2.1e9),  # see configs/xlstm_1_3b.py: d_ff=0 interpretation
        "deepseek-v3-671b": (620e9, 700e9),
        "granite-moe-3b-a800m": (2e9, 4.5e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        n = param_count(M.param_specs(cfg))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_moe_active_params_deepseek():
    from repro.launch.dryrun import active_params
    cfg = get_config("deepseek-v3-671b")
    a = active_params(cfg)
    assert 30e9 <= a <= 45e9, f"active {a/1e9:.1f}B (published ~37B)"


@pytest.mark.parametrize("name", ["qwen2.5-32b", "recurrentgemma-2b",
                                  "xlstm-1.3b", "deepseek-v3-671b"])
def test_shape_applicability(name):
    from repro.configs.base import applicable_shapes
    cfg = get_config(name)
    shapes = {s.name for s in applicable_shapes(cfg)}
    if cfg.subquadratic:
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes


def test_encoder_has_no_decode():
    from repro.configs.base import applicable_shapes
    cfg = get_config("hubert-xlarge")
    shapes = {s.name for s in applicable_shapes(cfg)}
    assert shapes == {"train_4k", "prefill_32k"}
