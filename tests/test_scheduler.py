"""serve/scheduler.py unit tests: EDF ordering, priority lanes,
deterministic tie-breaking, slack-safe preemption, starvation bounds,
and the gateway's bounded-queue overload policies — pure policy, no
threads (except where blocking IS the behaviour under test), no
devices."""
import threading
import time

import pytest

from repro.serve.scheduler import (INF, BoundedEDFScheduler, EDFScheduler,
                                   SlotView, preempt_victim)
from repro.serve.types import OverloadPolicy, QueueFull

# ------------------------------------------------------------ EDF ordering


def test_edf_pops_earliest_deadline_first():
    s = EDFScheduler()
    s.push("late", deadline=30.0, now=0.0)
    s.push("early", deadline=5.0, now=0.0)
    s.push("mid", deadline=12.0, now=0.0)
    assert [s.pop().payload for _ in range(3)] == ["early", "mid", "late"]
    assert s.pop() is None


def test_equal_deadlines_break_ties_by_submit_order():
    s = EDFScheduler()
    for k in range(8):
        s.push(k, deadline=10.0, now=0.0)
    assert [s.pop().payload for _ in range(8)] == list(range(8))


def test_deadline_less_requests_are_fifo_among_themselves():
    s = EDFScheduler(starvation_horizon=60.0)
    # submitted at increasing times -> increasing effective deadlines
    for k in range(5):
        s.push(k, deadline=None, now=float(k))
    assert [s.pop().payload for _ in range(5)] == list(range(5))


def test_starvation_horizon_bounds_deadline_less_wait():
    """A deadline-less request submitted at t=0 with horizon H outranks
    every deadline-carrying arrival whose deadline lies past t+H — an
    unbounded urgent stream cannot starve it forever."""
    s = EDFScheduler(starvation_horizon=10.0)
    s.push("best-effort", deadline=None, now=0.0)     # eff deadline 10
    s.push("tight", deadline=4.0, now=1.0)            # beats it
    for k in range(20):
        s.push(f"later-{k}", deadline=11.0 + k, now=2.0)  # all lose to it
    assert s.pop().payload == "tight"
    assert s.pop().payload == "best-effort"


def test_repush_with_original_seq_preserves_rank():
    """A parked (preempted) entry re-enters with its original sequence
    number and effective deadline, so it resumes exactly where EDF had
    placed it — ahead of anything submitted after it."""
    s = EDFScheduler()
    a = s.push("a", deadline=10.0, now=0.0)
    s.push("b", deadline=10.0, now=0.0)
    popped = s.pop()
    assert popped.payload == "a"
    # park + re-admit
    s.push("a", deadline=10.0, now=5.0, seq=a.seq, eff_deadline=a.eff_deadline)
    assert s.pop().payload == "a"
    assert s.pop().payload == "b"


def test_push_is_thread_safe_and_counts():
    s = EDFScheduler()
    n, per = 8, 50

    def producer(k):
        for i in range(per):
            s.push((k, i), deadline=float(k * per + i), now=0.0)

    ts = [threading.Thread(target=producer, args=(k,)) for k in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(s) == n * per
    assert s.pushed == n * per
    seen = set()
    prev = -1.0
    while (e := s.pop()) is not None:
        assert e.eff_deadline >= prev
        prev = e.eff_deadline
        seen.add(e.payload)
    assert len(seen) == n * per
    assert s.popped == n * per


# ----------------------------------------------------------- priority lane


def test_priority_outranks_any_deadline():
    s = EDFScheduler()
    s.push("tightest", deadline=0.1, now=0.0)
    s.push("urgent-flag", deadline=1000.0, now=0.0, priority=1)
    s.push("urgent-none", deadline=None, now=0.0, priority=2)
    assert [s.pop().payload for _ in range(3)] == \
        ["urgent-none", "urgent-flag", "tightest"]


def test_equal_priority_falls_back_to_edf_then_seq():
    s = EDFScheduler()
    s.push("b", deadline=20.0, now=0.0, priority=1)
    s.push("a", deadline=10.0, now=0.0, priority=1)
    s.push("c", deadline=10.0, now=0.0, priority=1)
    # same priority: deadline first (a before c by submit order), b last
    assert [s.pop().payload for _ in range(3)] == ["a", "c", "b"]


def test_repush_preserves_priority_rank():
    s = EDFScheduler()
    e = s.push("parked", deadline=50.0, now=0.0, priority=3)
    s.pop()
    s.push("later", deadline=1.0, now=0.0)
    s.push("parked", deadline=50.0, now=0.0, seq=e.seq,
           eff_deadline=e.eff_deadline, priority=e.priority)
    assert s.pop().payload == "parked"


# ------------------------------------------------- bounded queue: policies


def test_unbounded_capacity_never_applies_policy():
    s = BoundedEDFScheduler(capacity=None, policy=OverloadPolicy.REJECT)
    for k in range(100):
        entry, shed = s.offer(k, deadline=float(k), now=0.0)
        assert entry is not None and shed is None
    assert len(s) == 100 and s.rejected == 0 and s.shed_count == 0


def test_reject_policy_fails_fast_with_typed_error():
    s = BoundedEDFScheduler(capacity=2, policy="reject")
    s.offer("a", deadline=1.0, now=0.0)
    s.offer("b", deadline=2.0, now=0.0)
    with pytest.raises(QueueFull):
        s.offer("c", deadline=0.5, now=0.0)
    assert s.rejected == 1
    assert len(s) == 2              # queue untouched by the rejection
    assert s.pop().payload == "a"   # and order preserved


def test_shed_policy_evicts_latest_effective_deadline():
    s = BoundedEDFScheduler(capacity=3, policy="shed-latest-deadline",
                            starvation_horizon=60.0)
    s.offer("keep-5", deadline=5.0, now=0.0)
    s.offer("shed-me", deadline=90.0, now=0.0)
    s.offer("keep-10", deadline=10.0, now=0.0)
    entry, shed = s.offer("keep-7", deadline=7.0, now=0.0)
    assert entry is not None and shed.payload == "shed-me"
    assert s.shed_count == 1
    assert [s.pop().payload for _ in range(3)] == \
        ["keep-5", "keep-7", "keep-10"]


def test_shed_policy_sheds_the_incoming_request_when_it_ranks_last():
    s = BoundedEDFScheduler(capacity=2, policy="shed-latest-deadline")
    s.offer("a", deadline=5.0, now=0.0)
    s.offer("b", deadline=10.0, now=0.0)
    entry, shed = s.offer("late", deadline=99.0, now=0.0)
    assert entry is None and shed.payload == "late"
    assert len(s) == 2 and s.shed_count == 1
    # a deadline-less incoming ranks by the starvation horizon
    entry, shed = s.offer("horizon", deadline=None, now=0.0)
    assert entry is None and shed.payload == "horizon"


def test_shed_policy_never_evicts_higher_priority():
    s = BoundedEDFScheduler(capacity=2, policy="shed-latest-deadline")
    s.offer("vip", deadline=500.0, now=0.0, priority=1)
    s.offer("norm", deadline=1.0, now=0.0)
    # incoming normal-priority with a tighter deadline than the VIP's:
    # the shed victim must be the lower-priority entry
    entry, shed = s.offer("norm2", deadline=0.5, now=0.0)
    assert shed.payload == "norm"
    assert [s.pop().payload for _ in range(2)] == ["vip", "norm2"]


def test_block_policy_waits_for_a_pop_to_make_room():
    s = BoundedEDFScheduler(capacity=1, policy="block")
    s.offer("first", deadline=1.0, now=0.0)
    admitted = []

    def submitter():
        entry, _ = s.offer("second", deadline=2.0, now=0.0)
        admitted.append(entry)

    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.1)
    assert not admitted, "offer() returned while the queue was full"
    assert s.pop().payload == "first"   # pop frees a slot -> wakes waiter
    t.join(timeout=5.0)
    assert not t.is_alive() and admitted[0].payload == "second"
    assert s.pop().payload == "second"


def test_block_policy_timeout_and_close_release_waiters():
    s = BoundedEDFScheduler(capacity=1, policy="block")
    s.offer("first", deadline=1.0, now=0.0)
    with pytest.raises(QueueFull):
        s.offer("timed-out", deadline=2.0, now=0.0, timeout=0.05)
    results = []

    def submitter():
        try:
            s.offer("stranded", deadline=2.0, now=0.0)
        except RuntimeError as e:
            results.append(e)

    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.05)
    s.close()                        # shutdown must not strand the waiter
    t.join(timeout=5.0)
    assert not t.is_alive() and len(results) == 1
    with pytest.raises(RuntimeError):
        s.offer("after-close", deadline=1.0, now=0.0)


def test_pop_ready_skips_blocked_entries_in_rank_order():
    s = BoundedEDFScheduler(capacity=8)
    s.offer(("meshA", 1), deadline=1.0, now=0.0)
    s.offer(("meshA", 2), deadline=2.0, now=0.0)
    s.offer(("meshB", 3), deadline=3.0, now=0.0)
    # meshA saturated: the best READY entry is meshB's, despite its later
    # deadline — no head-of-line blocking across meshes
    e = s.pop_ready(lambda p: p[0] != "meshA")
    assert e.payload == ("meshB", 3)
    assert s.pop_ready(lambda p: p[0] != "meshA") is None
    assert len(s) == 2
    # unblocked: rank order resumes
    assert s.pop_ready(lambda p: True).payload == ("meshA", 1)


def test_pop_ready_key_evaluates_each_blocked_bucket_once():
    """Bucket-aware gating for canary pairs: readiness is per GROUP, so
    a blocked bucket's predicate runs once per scan, not once per queued
    entry — and the outcome is identical to the un-keyed scan."""
    s = BoundedEDFScheduler(capacity=16)
    for i in range(5):
        s.offer(("meshA", i), deadline=float(i), now=0.0)
    s.offer(("meshB", 9), deadline=99.0, now=0.0)
    calls = []

    def ready(p):
        calls.append(p[0])
        return p[0] != "meshA"

    e = s.pop_ready(ready, key=lambda p: p[0])
    assert e.payload == ("meshB", 9)
    assert calls == ["meshA", "meshB"]      # 5 meshA entries, ONE call
    # a ready group is still evaluated per entry (a pop may consume the
    # readiness), and rank order within the group is preserved
    calls.clear()
    assert s.pop_ready(ready, key=lambda p: p[0]) is None
    assert calls == ["meshA"]
    assert len(s) == 5
    assert s.pop_ready(lambda p: True,
                       key=lambda p: p[0]).payload == ("meshA", 0)


# ----------------------------------------------------------- target_slots


def test_target_slots_scales_with_rate_and_clamps():
    from repro.serve.scheduler import target_slots

    # no signal -> floor width
    assert target_slots(0.0, 1.0, 2, 8) == 2
    assert target_slots(-1.0, 1.0, 2, 8) == 2
    # proportional growth, rounded up to even (shardable widths)
    assert target_slots(0.5, 1.0, 2, 8) == 2
    assert target_slots(1.0, 1.0, 2, 8) == 2
    assert target_slots(2.0, 1.0, 2, 8) == 4
    assert target_slots(2.5, 1.0, 2, 8) == 6    # ceil(2.5) = 3 -> even 6
    assert target_slots(3.0, 1.0, 2, 8) == 6
    # clamped at the ceiling; base_rate rescales the whole curve
    assert target_slots(100.0, 1.0, 2, 8) == 8
    assert target_slots(100.0, 50.0, 2, 8) == 4
    with pytest.raises(ValueError, match="min_slots"):
        target_slots(1.0, 1.0, 1, 8)
    with pytest.raises(ValueError, match="max_slots"):
        target_slots(1.0, 1.0, 4, 2)


# ------------------------------------------------- width-ladder policies


def test_ladder_rungs_clamps_and_always_includes_max_width():
    from repro.serve.scheduler import DEFAULT_LADDER, ladder_rungs

    assert DEFAULT_LADDER == (2, 4, 8, 16)
    assert ladder_rungs(8) == (2, 4, 8)
    assert ladder_rungs(16) == (2, 4, 8, 16)
    # a max width off the ladder is appended, over-wide rungs dropped
    assert ladder_rungs(6, (2, 4, 8, 16)) == (2, 4, 6)
    # duplicates collapse; max_width == a rung stays a single entry
    assert ladder_rungs(4, (2, 2, 4)) == (2, 4)
    # degenerate ladder still serves full occupancy
    assert ladder_rungs(8, ()) == (8,)
    with pytest.raises(ValueError, match="min_width"):
        ladder_rungs(8, min_width=1)
    with pytest.raises(ValueError, match="max_width"):
        ladder_rungs(1)


def test_rung_for_picks_smallest_sufficient_width():
    from repro.serve.scheduler import rung_for

    rungs = (2, 4, 8)
    assert rung_for(0, rungs) == 2      # idle shard stays at the floor
    assert rung_for(2, rungs) == 2
    assert rung_for(3, rungs) == 4
    assert rung_for(4, rungs) == 4
    assert rung_for(5, rungs) == 8
    assert rung_for(99, rungs) == 8     # out-of-range caps clamp to top


def test_shape_class_for_smallest_containing_class():
    from repro.serve.scheduler import shape_class_for

    classes = [(16, 8), (12, 4), (8, 8)]
    assert shape_class_for((10, 4), classes) == (12, 4)
    assert shape_class_for((12, 4), classes) == (12, 4)   # exact fit
    assert shape_class_for((8, 6), classes) == (8, 8)
    assert shape_class_for((13, 5), classes) == (16, 8)
    assert shape_class_for((20, 4), classes) is None      # no container
    # smallest AREA wins, ties break lexicographically (deterministic)
    assert shape_class_for((4, 4), [(8, 8), (16, 4), (4, 16)]) == (4, 16)


# --------------------------------------------------------- preempt_victim

_SPI = 1.0  # seconds per iteration, fixed for readability


def _slot(deadline=INF, left=10, preemptible=True):
    return SlotView(deadline=deadline, iters_left=left,
                    preemptible=preemptible)


def test_no_preemption_when_waiting_makes_the_deadline():
    # next natural completion in 2 iters; candidate needs 3; deadline 10s out
    slots = [_slot(left=2), _slot(left=8)]
    assert preempt_victim(10.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_no_preemption_when_a_lane_is_free():
    slots = [None, _slot(left=50)]
    assert preempt_victim(1.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_no_preemption_for_deadline_less_or_unestimated():
    slots = [_slot(left=50), _slot(left=50)]
    assert preempt_victim(INF, 3, slots, now=0.0, sec_per_iter=_SPI) is None
    assert preempt_victim(1.0, 3, slots, now=0.0, sec_per_iter=0.0) is None


def test_no_preemption_when_candidate_is_infeasible_anyway():
    # deadline 2s, needs 3 iters at 1s each: even an immediate slot misses;
    # evicting a victim would trade one miss for a possible second
    slots = [_slot(left=50), _slot(left=50)]
    assert preempt_victim(2.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_preemption_fires_only_when_victim_provably_safe():
    """Urgent candidate (misses if it waits, makes it if admitted now).
    The only occupant has a deadline that eviction would blow -> None;
    give it slack -> it becomes the victim."""
    urgent = dict(deadline=6.0, iters_needed=4, now=0.0, sec_per_iter=_SPI)
    # victim would finish at 4 + 10 = 14 > its deadline 12: unsafe
    tight = [_slot(deadline=12.0, left=10), _slot(deadline=12.0, left=10)]
    assert preempt_victim(urgent["deadline"], urgent["iters_needed"], tight,
                          urgent["now"], urgent["sec_per_iter"]) is None
    # deadline 20 leaves slack 6 after eviction: safe -> evicted
    slack = [_slot(deadline=20.0, left=10), _slot(deadline=12.0, left=10)]
    assert preempt_victim(urgent["deadline"], urgent["iters_needed"], slack,
                          urgent["now"], urgent["sec_per_iter"]) == 0


def test_victim_choice_maximizes_slack_and_ties_break_low():
    # both deadline-less (infinite slack): deterministic lowest lane
    slots = [_slot(left=10), _slot(left=10)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 0
    # lane 1 has more slack than lane 2 -> lane 1
    slots = [_slot(deadline=13.0, left=10),     # unsafe (finish 14)
             _slot(deadline=40.0, left=10),     # slack 26
             _slot(deadline=20.0, left=10)]     # slack 6
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 1


def test_non_preemptible_slots_are_skipped():
    slots = [_slot(left=10, preemptible=False), _slot(deadline=20.0, left=10)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 1
    slots = [_slot(left=10, preemptible=False),
             _slot(left=10, preemptible=False)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) is None
