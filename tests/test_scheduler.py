"""serve/scheduler.py unit tests: EDF ordering, deterministic
tie-breaking, slack-safe preemption, and starvation bounds — pure policy,
no threads, no devices."""
import threading

from repro.serve.scheduler import INF, EDFScheduler, SlotView, preempt_victim

# ------------------------------------------------------------ EDF ordering


def test_edf_pops_earliest_deadline_first():
    s = EDFScheduler()
    s.push("late", deadline=30.0, now=0.0)
    s.push("early", deadline=5.0, now=0.0)
    s.push("mid", deadline=12.0, now=0.0)
    assert [s.pop().payload for _ in range(3)] == ["early", "mid", "late"]
    assert s.pop() is None


def test_equal_deadlines_break_ties_by_submit_order():
    s = EDFScheduler()
    for k in range(8):
        s.push(k, deadline=10.0, now=0.0)
    assert [s.pop().payload for _ in range(8)] == list(range(8))


def test_deadline_less_requests_are_fifo_among_themselves():
    s = EDFScheduler(starvation_horizon=60.0)
    # submitted at increasing times -> increasing effective deadlines
    for k in range(5):
        s.push(k, deadline=None, now=float(k))
    assert [s.pop().payload for _ in range(5)] == list(range(5))


def test_starvation_horizon_bounds_deadline_less_wait():
    """A deadline-less request submitted at t=0 with horizon H outranks
    every deadline-carrying arrival whose deadline lies past t+H — an
    unbounded urgent stream cannot starve it forever."""
    s = EDFScheduler(starvation_horizon=10.0)
    s.push("best-effort", deadline=None, now=0.0)     # eff deadline 10
    s.push("tight", deadline=4.0, now=1.0)            # beats it
    for k in range(20):
        s.push(f"later-{k}", deadline=11.0 + k, now=2.0)  # all lose to it
    assert s.pop().payload == "tight"
    assert s.pop().payload == "best-effort"


def test_repush_with_original_seq_preserves_rank():
    """A parked (preempted) entry re-enters with its original sequence
    number and effective deadline, so it resumes exactly where EDF had
    placed it — ahead of anything submitted after it."""
    s = EDFScheduler()
    a = s.push("a", deadline=10.0, now=0.0)
    s.push("b", deadline=10.0, now=0.0)
    popped = s.pop()
    assert popped.payload == "a"
    # park + re-admit
    s.push("a", deadline=10.0, now=5.0, seq=a.seq, eff_deadline=a.eff_deadline)
    assert s.pop().payload == "a"
    assert s.pop().payload == "b"


def test_push_is_thread_safe_and_counts():
    s = EDFScheduler()
    n, per = 8, 50

    def producer(k):
        for i in range(per):
            s.push((k, i), deadline=float(k * per + i), now=0.0)

    ts = [threading.Thread(target=producer, args=(k,)) for k in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(s) == n * per
    assert s.pushed == n * per
    seen = set()
    prev = -1.0
    while (e := s.pop()) is not None:
        assert e.eff_deadline >= prev
        prev = e.eff_deadline
        seen.add(e.payload)
    assert len(seen) == n * per
    assert s.popped == n * per


# --------------------------------------------------------- preempt_victim

_SPI = 1.0  # seconds per iteration, fixed for readability


def _slot(deadline=INF, left=10, preemptible=True):
    return SlotView(deadline=deadline, iters_left=left,
                    preemptible=preemptible)


def test_no_preemption_when_waiting_makes_the_deadline():
    # next natural completion in 2 iters; candidate needs 3; deadline 10s out
    slots = [_slot(left=2), _slot(left=8)]
    assert preempt_victim(10.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_no_preemption_when_a_lane_is_free():
    slots = [None, _slot(left=50)]
    assert preempt_victim(1.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_no_preemption_for_deadline_less_or_unestimated():
    slots = [_slot(left=50), _slot(left=50)]
    assert preempt_victim(INF, 3, slots, now=0.0, sec_per_iter=_SPI) is None
    assert preempt_victim(1.0, 3, slots, now=0.0, sec_per_iter=0.0) is None


def test_no_preemption_when_candidate_is_infeasible_anyway():
    # deadline 2s, needs 3 iters at 1s each: even an immediate slot misses;
    # evicting a victim would trade one miss for a possible second
    slots = [_slot(left=50), _slot(left=50)]
    assert preempt_victim(2.0, 3, slots, now=0.0, sec_per_iter=_SPI) is None


def test_preemption_fires_only_when_victim_provably_safe():
    """Urgent candidate (misses if it waits, makes it if admitted now).
    The only occupant has a deadline that eviction would blow -> None;
    give it slack -> it becomes the victim."""
    urgent = dict(deadline=6.0, iters_needed=4, now=0.0, sec_per_iter=_SPI)
    # victim would finish at 4 + 10 = 14 > its deadline 12: unsafe
    tight = [_slot(deadline=12.0, left=10), _slot(deadline=12.0, left=10)]
    assert preempt_victim(urgent["deadline"], urgent["iters_needed"], tight,
                          urgent["now"], urgent["sec_per_iter"]) is None
    # deadline 20 leaves slack 6 after eviction: safe -> evicted
    slack = [_slot(deadline=20.0, left=10), _slot(deadline=12.0, left=10)]
    assert preempt_victim(urgent["deadline"], urgent["iters_needed"], slack,
                          urgent["now"], urgent["sec_per_iter"]) == 0


def test_victim_choice_maximizes_slack_and_ties_break_low():
    # both deadline-less (infinite slack): deterministic lowest lane
    slots = [_slot(left=10), _slot(left=10)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 0
    # lane 1 has more slack than lane 2 -> lane 1
    slots = [_slot(deadline=13.0, left=10),     # unsafe (finish 14)
             _slot(deadline=40.0, left=10),     # slack 26
             _slot(deadline=20.0, left=10)]     # slack 6
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 1


def test_non_preemptible_slots_are_skipped():
    slots = [_slot(left=10, preemptible=False), _slot(deadline=20.0, left=10)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) == 1
    slots = [_slot(left=10, preemptible=False),
             _slot(left=10, preemptible=False)]
    assert preempt_victim(6.0, 4, slots, now=0.0, sec_per_iter=_SPI) is None
