"""Serving-data flywheel tests (serve/flywheel.py + its data layer).

Four layers:
  * unit: windowed ``TagStats`` (time-decayed canary evidence),
    ``LoadCase.from_problem`` round-trip (the harvester's inverse of
    ``problem()``), ``HarvestLog`` dedup/bounds/acceptance-cutoff and
    bounded on-disk spooling, ``registry.sweep`` keep-policy;
  * real data layer: ``harvest_dataset`` regenerates deduplicated
    fallback cases as trajectories, ``finetune_from_tag`` warm-starts
    bitwise from the base checkpoint (``steps=0``) and registers a
    mesh-specialized child with lineage;
  * controller against fake engines: the full IDLE -> HARVESTING ->
    TRAINING -> CANARY -> PROMOTED/ROLLED-BACK machine with injected
    harvest/train layers, one-cycle-per-bucket, cooldown, error path;
  * property-based: random interleavings of traffic / completion /
    tick / flush / sweep — no request dropped, zero mis-tags, lineage
    consistent, at most one cycle in flight per bucket, leases balance
    after shutdown.
"""
import collections
import dataclasses
import random
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from test_gateway import _FakeEngine, wait_until

from repro.configs.cronet import CRONetConfig
from repro.fea import dataset as ds_mod
from repro.serve import (FlywheelController, FlywheelState, HarvestLog,
                         ModelRegistry, RegistryRetention, TagStats,
                         TopoGateway, TopoRequest)

U_SCALE = 50.0
CFG = CRONetConfig(nelx=12, nely=4, hist_len=3)


def _sreq(cronet_iters, fea_iters, deadline=None, met=None):
    return SimpleNamespace(cronet_iters=cronet_iters, fea_iters=fea_iters,
                           deadline=deadline, deadline_met=met,
                           latency_s=0.01)


# ----------------------------------------------------- windowed TagStats


def test_tagstats_window_tracks_recent_traffic():
    ts = TagStats(window=3)
    for _ in range(4):
        ts.record(_sreq(0, 10))         # old, all-FEA traffic
    for _ in range(3):
        ts.record(_sreq(10, 0))         # recent, all-NN traffic
    assert ts.completed == 7
    assert ts.recent_completed == 3
    # lifetime blends both phases; the window sees only the recovery
    assert ts.cronet_hit_rate == pytest.approx(30 / 70)
    assert ts.recent_cronet_hit_rate == pytest.approx(1.0)
    snap = ts.snapshot()
    assert snap["recent_completed"] == 3
    assert snap["recent_cronet_hit_rate"] == pytest.approx(1.0)


def test_tagstats_unwindowed_recent_aliases_lifetime():
    ts = TagStats()
    ts.record(_sreq(3, 1, deadline=1.0, met=True))
    ts.record(_sreq(1, 3, deadline=1.0, met=False))
    assert ts.recent_completed == ts.completed == 2
    assert ts.recent_cronet_hit_rate == ts.cronet_hit_rate
    assert ts.recent_deadline_hit_rate == ts.deadline_hit_rate == 0.5


# ------------------------------------------------- LoadCase.from_problem


def test_loadcase_from_problem_roundtrip():
    case = ds_mod.LoadCase(load_frac=0.3, load=(0.25, -0.9), volfrac=0.42)
    prob = case.problem(12, 4)
    back = ds_mod.LoadCase.from_problem(prob)
    assert back.kind == "harvest"
    # the recovered node quantizes load_frac to the mesh, so compare
    # through the dedup key of the requantized original
    requant = dataclasses.replace(
        case, load_frac=case.load_node(12)[0] / 12)
    assert back.key() == dataclasses.replace(requant,
                                             kind="harvest").key()
    assert back.load == pytest.approx(case.load)
    assert back.volfrac == pytest.approx(case.volfrac)


# ------------------------------------------------------------ HarvestLog


def _hreq(uid, nelx=12, nely=4, n_iter=10, load_frac=None,
          cronet_iters=None, fea_iters=None):
    """A completed-request stand-in carrying a point-load vector the
    harvester can invert."""
    lf = load_frac if load_frac is not None else (uid % 7) / 10
    f = np.zeros(2 * (nelx + 1) * (nely + 1))
    node = min(int(round(lf * nelx)), nelx - 1) * (nely + 1)
    f[2 * node + 1] = -1.0
    prob = SimpleNamespace(nelx=nelx, nely=nely, f=f, volfrac=0.4)
    req = TopoRequest(uid=uid, problem=prob, n_iter=n_iter)
    if cronet_iters is not None:
        req.cronet_iters, req.fea_iters = cronet_iters, fea_iters
    return req


def test_harvest_log_cutoff_dedup_and_bounds():
    log = HarvestLog(capacity=3, accept_below=0.8)
    assert not log.record(_hreq(0, cronet_iters=9, fea_iters=1))   # accepted
    assert not log.record(_hreq(1, cronet_iters=0, fea_iters=0))   # empty
    assert log.record(_hreq(2, load_frac=0.1, cronet_iters=1, fea_iters=9))
    # same load case again: deduplicated, not duplicated
    assert log.record(_hreq(3, load_frac=0.1, cronet_iters=2, fea_iters=8))
    assert len(log.rejected_cases((12, 4))) == 1
    assert log.duplicates == 1
    # capacity bound: newest distinct cases win
    for i, lf in enumerate((0.2, 0.3, 0.4, 0.5)):
        log.record(_hreq(10 + i, load_frac=lf, cronet_iters=0,
                         fea_iters=10))
    cases = log.rejected_cases((12, 4))
    assert len(cases) == 3
    # load_frac comes back requantized to the mesh (node / nelx)
    assert [int(round(c.load_frac * 12)) for c in cases] == [4, 5, 6]
    assert log.snapshot()["buckets"] == {"12x4": 3}


def test_harvest_log_spool_roundtrip_and_bounds(tmp_path):
    spool = str(tmp_path / "spool")
    log = HarvestLog(capacity=8, spool_dir=spool, spool_limit=3)
    for i, lf in enumerate((0.1, 0.2, 0.3, 0.4, 0.5)):
        log.record(_hreq(i, load_frac=lf, cronet_iters=0, fea_iters=10))
    log.flush()
    # a fresh process (new log, same spool) keeps the newest
    # spool_limit distinct cases
    log2 = HarvestLog(capacity=8, spool_dir=spool, spool_limit=3)
    cases = log2.rejected_cases((12, 4))
    assert [int(round(c.load_frac * 12)) for c in cases] == [4, 5, 6]
    # memory wins over the spool on a duplicate key, and clear()
    # removes both sides
    log2.record(_hreq(9, load_frac=0.4, cronet_iters=0, fea_iters=10))
    assert len(log2.rejected_cases((12, 4))) == 3
    log2.clear((12, 4))
    assert log2.rejected_cases((12, 4)) == []
    assert log.rejected_cases((12, 4), include_spool=False) != []


# ------------------------------------------------- registry sweep policy


def test_registry_sweep_keep_policy(tmp_path):
    reg = ModelRegistry(str(tmp_path / "reg"))
    p = {"w": np.float32(1.0)}
    reg.register(p, CFG, U_SCALE, tag="base")
    for i in range(4):
        reg.register(p, CFG, U_SCALE, tag=f"base-ft{i}", mesh=(12, 4),
                     parent="base")
    reg.register(p, CFG, U_SCALE, tag="pinned-old", mesh=(12, 4),
                 parent="base", pin=True)
    reg.register(p, CFG, U_SCALE, tag="other", mesh=(16, 8))
    reg.acquire("base-ft0")          # serving somewhere: leased
    dropped = reg.sweep(keep_per_lineage=2)
    # the (12,4) x base lineage keeps its newest two + pinned + leased
    assert set(dropped) == {"base-ft1"}
    assert set(reg.tags()) == {"base", "base-ft0", "base-ft2", "base-ft3",
                               "pinned-old", "other"}
    reg.release("base-ft0")
    dropped = reg.sweep(keep_per_lineage=1)
    assert set(dropped) == {"base-ft0", "base-ft2"}
    # a loadable survivor: sweep prunes checkpoints too, not just index
    from repro.checkpoint import manager as ckpt
    rec = reg.get("base-ft3")
    assert rec.parent == "base" and rec.mesh == (12, 4)
    tree, _ = ckpt.restore(reg.ckpt_dir,
                           {"params": {"w": np.zeros((), np.float32)}},
                           step=rec.version)
    assert tree["params"]["w"] == np.float32(1.0)


def test_registry_retention_driver(tmp_path):
    reg = ModelRegistry(str(tmp_path / "reg"))
    p = {"w": np.float32(1.0)}
    for i in range(3):
        reg.register(p, CFG, U_SCALE, tag=f"v{i}", mesh=(12, 4),
                     parent=f"v{i - 1}" if i else None)
    ret = RegistryRetention(reg, keep_per_lineage=1, interval_s=3600.0)
    assert set(ret.sweep()) == {"v0", "v1"}
    assert ret.maybe_sweep() == []       # inside the interval: no-op
    assert ret.sweeps == 1 and ret.dropped == ["v0", "v1"]


# --------------------------------------- real data layer: harvest + tune


@pytest.fixture(scope="module")
def harvested_ds():
    cases = [ds_mod.LoadCase(load_frac=0.25, volfrac=0.4, kind="harvest"),
             ds_mod.LoadCase(load_frac=0.6, load=(0.3, -0.8),
                             volfrac=0.5, kind="harvest")]
    return ds_mod.harvest_dataset(cases, (10, 4), cfg=CFG, n_iter=7,
                                  max_cases=8)


def test_harvest_dataset_regenerates_trajectories(harvested_ds):
    ds = harvested_ds
    assert ds is not None
    assert ds.n_trajectories == 2
    # n_iter=7, hist_len=3 -> 4 windows per trajectory, on the BUCKET
    # mesh (10x4), regardless of the training cfg's template mesh
    assert ds.n_windows == 8
    assert ds.windows.shape[2:] == (4, 10, 1)
    assert all(c.kind == "harvest" for c in ds.cases)
    # empty / below-dedup inputs are a None, not a crash
    assert ds_mod.harvest_dataset([], (10, 4), cfg=CFG) is None


def test_harvest_dataset_dedups_and_truncates(harvested_ds):
    dup = [ds_mod.LoadCase(load_frac=0.25, volfrac=0.4),
           ds_mod.LoadCase(load_frac=0.25, volfrac=0.4)]
    ds = ds_mod.harvest_dataset(dup, (10, 4), cfg=CFG, n_iter=7)
    assert ds.n_trajectories == 1
    newest = [ds_mod.LoadCase(load_frac=i / 10, volfrac=0.4)
              for i in range(1, 5)]
    ds = ds_mod.harvest_dataset(newest, (10, 4), cfg=CFG, n_iter=7,
                                max_cases=2)
    assert ds.n_trajectories == 2
    assert [round(c.load_frac, 2) for c in ds.cases] == [0.3, 0.4]


@pytest.fixture(scope="module")
def base_registry(tmp_path_factory, harvested_ds):
    """A registry holding a real (randomly-initialized) base version."""
    from repro.common import materialize
    from repro.core import cronet
    reg = ModelRegistry(str(tmp_path_factory.mktemp("reg")))
    specs = cronet.param_specs(dataclasses.replace(CFG, dtype="float32"))
    import jax
    params = materialize(specs, jax.random.key(7))
    reg.register(params, CFG, U_SCALE, tag="base",
                 load_cases=[ds_mod.LoadCase(load_frac=0.4).describe()])
    return reg


def test_finetune_from_tag_warm_start_and_lineage(base_registry,
                                                  harvested_ds):
    from repro.fea import train_cronet
    reg = base_registry
    base_params, _ = reg.load("base")
    record, result = train_cronet.finetune_from_tag(
        reg, "base", (10, 4), harvested_ds, steps=0, replay_cases=0,
        verbose=False)
    # steps=0 is a pure warm start: bitwise the base master weights
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(base_params),
                    jax.tree_util.tree_leaves(result.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert record.tag == "base-ft10x4"
    assert record.parent == "base" and record.mesh == (10, 4)
    assert record.metrics["finetuned_from"] == "base"
    assert record.metrics["harvested_trajectories"] == 2
    # the child resolves for its bucket (FE-CNN-style specialization)
    assert reg.latest(mesh=(10, 4)).tag == record.tag
    assert reg.latest().tag == "base"       # never the fleet default
    # a second fine-tune gets a fresh tag (versions are immutable)
    record2, _ = train_cronet.finetune_from_tag(
        reg, "base", (10, 4), harvested_ds, steps=0, replay_cases=0,
        verbose=False)
    assert record2.tag == "base-ft10x4.2"


def test_finetune_replay_mix_concatenates(base_registry, harvested_ds):
    from repro.fea import train_cronet
    record, result = train_cronet.finetune_from_tag(
        base_registry, "base", (10, 4), harvested_ds, steps=2,
        replay_cases=1, replay_n_iter=7, verbose=False)
    # 2 harvested trajectories + 1 replayed from the base checkpoint's
    # recorded training distribution (the anti-forgetting mix)
    assert len(result.cases) == 3
    kinds = [c.kind for c in result.cases]
    assert kinds.count("harvest") == 2
    assert record.parent == "base"


# ----------------------------------------- controller with fake engines


def _fly_stack(tmp_path, *, primary_frac=0.2, child_frac=0.9,
               harvest_kw=None, **ctl_kw):
    """Registry + fake-engine gateway + harvest log + controller with
    injected harvest/train layers — the whole loop, device-free."""
    reg = ModelRegistry(str(tmp_path / "reg"))
    reg.register({"cronet_frac": np.float32(primary_frac)}, CFG, U_SCALE,
                 tag="prod")
    built = collections.defaultdict(list)

    def factory(nelx, nely):
        e = _FakeEngine(nelx, nely, model_tag="prod",
                        cronet_frac=primary_frac)
        built[(nelx, nely)].append(e)
        return e

    log = HarvestLog(**(harvest_kw or {"capacity": 16}))
    gw = TopoGateway(SimpleNamespace(nelx=0, nely=0),
                     params={"cronet_frac": np.float32(primary_frac)},
                     u_scale=U_SCALE, engine_factory=factory,
                     registry=reg, model_tag="prod", max_pending=None,
                     harvest=log)

    def train_fn(base_tag, mesh, harvested):
        base = f"{base_tag}-ft{mesh[0]}x{mesh[1]}"
        taken, tag, k = set(reg.tags()), base, 2
        while tag in taken:
            tag, k = f"{base}.{k}", k + 1
        frac = child_frac() if callable(child_frac) else child_frac
        reg.register({"cronet_frac": np.float32(frac)}, CFG, U_SCALE,
                     tag=tag, mesh=mesh, parent=base_tag)
        return tag, {"cronet_frac": frac}, U_SCALE

    kw = dict(trigger_below=0.5, min_completed=8, min_harvest=2,
              cooldown_s=3600.0, canary_fraction=0.5,
              canary_min_requests=4, canary_margin=0.05,
              promote_after=4, promote_timeout=10.0,
              harvest_fn=lambda cases, mesh, base: cases,
              train_fn=train_fn)
    kw.update(ctl_kw)
    fly = FlywheelController(gw, log, **kw)
    return reg, gw, built, log, fly


def _complete_all(built):
    for engs in list(built.values()):
        for e in engs:
            while e.submitted:
                e.complete()


def _pump(gw, built, timeout=10):
    t0 = time.time()
    while not gw.drain(timeout=0.05):
        assert time.time() - t0 < timeout, "gateway did not drain"
        _complete_all(built)


def test_flywheel_full_cycle_promotes(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path)
    futs = [gw.submit(_hreq(i)) for i in range(10)]
    _pump(gw, built)
    assert fly.tick()
    # trigger fired: HARVESTING -> TRAINING -> CANARY ran synchronously
    live = fly.cycles()
    assert live["12x4"]["state"] == "canary"
    assert live["12x4"]["base_tag"] == "prod"
    child = live["12x4"]["child_tag"]
    assert reg.get(child).parent == "prod"
    # a second tick mid-canary must NOT start another cycle (or promote
    # before the windowed evidence is in)
    fly.tick()
    assert len(fly.cycles()) == 1 and len(fly.history) == 0
    # canary traffic: child wins 0.9 vs 0.2 on windowed acceptance
    futs += [gw.submit(_hreq(100 + i)) for i in range(16)]
    _pump(gw, built)
    fly.tick()
    assert fly.cycles() == {}
    assert [c.state for c in fly.history] == [FlywheelState.PROMOTED]
    assert gw.serving_tag((12, 4)) == child
    assert reg.get(child).promoted_at is not None
    kinds = [e.kind for e in gw.events]
    for k in ("flywheel-trigger", "flywheel-harvest", "flywheel-train",
              "flywheel-canary", "canary-start", "promote",
              "flywheel-promote"):
        assert k in kinds, k
    # zero dropped, zero mis-tagged — the acceptance-criteria invariant
    for f in futs:
        r = f.result(timeout=5)
        assert r.done and r.model_tag == r.routed_tag
    assert log.snapshot()["buckets"] == {}   # cleared on promotion
    gw.shutdown()
    assert reg.leased() == {}


def test_flywheel_regressing_child_rolls_back(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path, child_frac=0.0)
    futs = [gw.submit(_hreq(i)) for i in range(10)]
    _pump(gw, built)
    fly.tick()
    child = fly.cycles()["12x4"]["child_tag"]
    futs += [gw.submit(_hreq(100 + i)) for i in range(16)]
    _pump(gw, built)
    fly.tick()
    assert [c.state for c in fly.history] == [FlywheelState.ROLLED_BACK]
    # the bucket still serves the base model; the child stays in the
    # registry (retention, not rollback, is the reaper) but unleased
    assert gw.serving_tag((12, 4)) == "prod"
    assert child in reg.tags()
    kinds = [e.kind for e in gw.events]
    assert "rollback" in kinds and "flywheel-rollback" in kinds
    for f in futs:
        r = f.result(timeout=5)
        assert r.done and r.model_tag == r.routed_tag
    gw.shutdown()
    assert reg.leased() == {}


def test_flywheel_sequential_cycles_after_cooldown(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path, child_frac=0.0,
                                          cooldown_s=0.0)
    [gw.submit(_hreq(i)) for i in range(10)]
    _pump(gw, built)
    fly.tick()
    first = fly.cycles()["12x4"]["child_tag"]
    [gw.submit(_hreq(100 + i)) for i in range(16)]
    _pump(gw, built)
    fly.tick()       # rollback detected; cooldown_s=0 -> a NEW cycle
    #                  may start on the same bucket, sequentially
    assert fly.history[0].state is FlywheelState.ROLLED_BACK
    second = fly.cycles()["12x4"]["child_tag"]
    assert second != first
    assert reg.get(second).parent == "prod"
    gw.shutdown()
    assert reg.leased() == {}


def test_flywheel_too_few_harvested_cases_is_error_not_canary(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path, min_harvest=5)
    # one distinct load case, repeated: dedup leaves a single entry
    [gw.submit(_hreq(i, load_frac=0.3)) for i in range(10)]
    _pump(gw, built)
    fly.tick()
    assert [c.state for c in fly.history] == [FlywheelState.ERROR]
    assert "min_harvest" in fly.history[0].error
    assert set(reg.tags()) == {"prod"}      # nothing trained or canaried
    gw.shutdown()
    assert reg.leased() == {}


def test_flywheel_acceptable_bucket_never_triggers(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path, primary_frac=0.9)
    [gw.submit(_hreq(i)) for i in range(12)]
    _pump(gw, built)
    fly.tick()
    assert fly.cycles() == {} and fly.history == []
    gw.shutdown()


def test_flywheel_daemon_runs_unattended(tmp_path):
    reg, gw, built, log, fly = _fly_stack(tmp_path, interval_s=0.02)
    fly.start()
    try:
        [gw.submit(_hreq(i)) for i in range(10)]
        _pump(gw, built)
        assert wait_until(lambda: "12x4" in fly.cycles(), timeout=10)
        deadline = time.time() + 10
        while time.time() < deadline and not fly.history:
            [gw.submit(_hreq(1000 + random.randrange(10 ** 6)))
             for _ in range(4)]
            _pump(gw, built)
        assert fly.history and fly.history[0].state in (
            FlywheelState.PROMOTED, FlywheelState.ROLLED_BACK)
    finally:
        fly.stop()
        gw.shutdown()
    assert reg.leased() == {}


# ------------------------------------------------- property: interleavings


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_flywheel_random_interleavings_hold_invariants(seed):
    """Random interleavings of traffic / completion / tick / flush /
    sweep across two buckets, with the fine-tuned child randomly good
    or regressing: no request is ever dropped or mis-tagged, lineage
    stays consistent, at most one cycle is in flight per bucket, and
    every lease is returned by shutdown."""
    import pathlib
    import tempfile
    rng = random.Random(seed)
    tmp_path = pathlib.Path(tempfile.mkdtemp(prefix=f"fly{seed}-"))
    reg, gw, built, log, fly = _fly_stack(
        tmp_path, child_frac=lambda: rng.choice((0.0, 0.9)),
        cooldown_s=0.0, promote_timeout=0.2)
    ret = RegistryRetention(reg, keep_per_lineage=2, interval_s=0.0)
    meshes = [(12, 4), (16, 8)]
    futs, uid = [], 0
    for _ in range(70):
        op = rng.randrange(10)
        if op < 5:
            m = rng.choice(meshes)
            futs.append(gw.submit(_hreq(uid, nelx=m[0], nely=m[1])))
            uid += 1
        elif op < 8:
            engs = [e for el in built.values() for e in el if e.submitted]
            if engs:
                rng.choice(engs).complete()
        elif op < 9:
            fly.tick()
            live = fly.cycles()
            assert len(live) <= len(meshes)       # one per bucket, max
        else:
            ret.sweep()
    _pump(gw, built)
    for _ in range(6):                  # settle: advance/trigger/promote
        fly.tick()
        _pump(gw, built)
    # invariant: nothing dropped, nothing mis-tagged
    assert len(futs) == uid
    for f in futs:
        r = f.result(timeout=5)
        assert r.done and r.model_tag == r.routed_tag
    # invariant: lineage metadata consistent for every surviving child
    for cycle in fly.history:
        assert cycle.state.terminal
        if cycle.child_tag and cycle.child_tag in reg.tags():
            assert reg.get(cycle.child_tag).parent == cycle.base_tag
    # invariant: each bucket never saw overlapping cycles — every
    # terminal state was reached before the next trigger on that mesh
    per_mesh = collections.defaultdict(list)
    for cycle in fly.history:
        per_mesh[cycle.mesh].append(cycle)
    for cycles in per_mesh.values():
        for c in cycles:
            assert c.state.terminal
    # invariant: leases balance after rollback/promote + shutdown
    gw.shutdown()
    assert reg.leased() == {}


# --------------------------------------- harvest flush on gateway shutdown


def _spooling_stack(tmp_path):
    built = collections.defaultdict(list)

    def factory(nelx, nely):
        e = _FakeEngine(nelx, nely, model_tag="prod", cronet_frac=0.2)
        built[(nelx, nely)].append(e)
        return e

    log = HarvestLog(capacity=16, accept_below=0.8,
                     spool_dir=str(tmp_path))
    gw = TopoGateway(SimpleNamespace(nelx=0, nely=0), params=None,
                     u_scale=U_SCALE, engine_factory=factory,
                     max_pending=None, harvest=log)
    return gw, built, log


def test_gateway_shutdown_flushes_harvest_spool(tmp_path):
    """Regression: ``record()`` is in-memory by contract and the
    gateway never called ``harvest.flush()`` on shutdown — stop the
    process after a serve and every harvested case evaporated unless a
    flywheel daemon happened to have ticked. A restarted harvester must
    find the evidence in the spool."""
    gw, built, log = _spooling_stack(tmp_path)
    futs = [gw.submit(_hreq(i, load_frac=i / 10)) for i in range(3)]
    _pump(gw, built)
    assert all(f.result(timeout=5).done for f in futs)
    assert log.snapshot()["harvested"] == 3
    # the completion path never spools (it runs under the queue lock)
    assert not list(tmp_path.glob("harvest_*.jsonl"))
    gw.shutdown(wait=True)
    reborn = HarvestLog(capacity=16, accept_below=0.8,
                        spool_dir=str(tmp_path))
    assert len(reborn.rejected_cases((12, 4))) == 3


def test_async_gateway_shutdown_also_flushes_harvest(tmp_path):
    """The ``wait=False`` path has nobody left to flush after the
    dispatcher exits — the dispatcher itself must do it."""
    gw, built, log = _spooling_stack(tmp_path)
    futs = [gw.submit(_hreq(100 + i, load_frac=i / 10)) for i in range(2)]
    _pump(gw, built)
    assert all(f.result(timeout=5).done for f in futs)
    gw.shutdown(wait=False)
    assert wait_until(
        lambda: list(tmp_path.glob("harvest_*.jsonl")), timeout=10)
    assert len(HarvestLog(capacity=16, accept_below=0.8,
                          spool_dir=str(tmp_path))
               .rejected_cases((12, 4))) == 2
