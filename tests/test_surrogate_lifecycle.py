"""Surrogate lifecycle: multi-load-case dataset/training, the versioned
model registry, and hot-swappable checkpoints behind the gateway.

The load-bearing claim (the reason the subsystem exists): a surrogate
trained on ONE MBB trajectory scores a 0% CRONet hit rate on
off-distribution point loads — every serving request falls back to full
FEA — while the multi-load-case surrogate accepts on held-out loads it
never saw. Tier-1 asserts the separation (multi > 0, single == 0); the
nightly `slow` tier runs the full-budget training and asserts >= 30%.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.common import materialize
from repro.configs.cronet import get_cronet_config
from repro.core import cronet
from repro.fea import dataset as dsm
from repro.fea import fea2d, hybrid, simp, train_cronet
from repro.serve import (ModelRegistry, NoModelError, TopoGateway,
                         TopoRequest, TopoServingEngine)

THRESHOLD = 0.15     # residual gate for the off-distribution serving runs


def _tiny_cfg():
    return dataclasses.replace(get_cronet_config("small"),
                               nelx=12, nely=4, hist_len=3)


def _held_out_loads(cfg, n=5, seed=99):
    """Off-distribution requests: pure-vertical point loads at positions/
    magnitudes the training sampler never drew (the serve_topo demo's
    request generator)."""
    rng = np.random.default_rng(seed)
    return [fea2d.point_load_problem(
        cfg.nelx, cfg.nely,
        load_node=(int(rng.integers(0, cfg.nelx - 1)), 0),
        load=(0.0, float(-0.5 - rng.random()))) for _ in range(n)]


# ---------------------------------------------------------------- sampler


def test_load_case_sampler_covers_the_request_space():
    cases = dsm.sample_load_cases(16, seed=3)
    assert cases[0].kind == "mbb"            # distribution anchored at MBB
    assert len(cases) == 16
    for c in cases[1:]:
        assert 0.0 <= c.load_frac < 1.0
        fx, fy = c.load
        assert fy < 0.0                      # downward-ish load
        mag = float(np.hypot(fx, fy))
        assert 0.5 <= mag <= 1.5
        node = c.load_node(12)
        assert 0 <= node[0] <= 11            # off the degenerate column
        c.problem(12, 4)                     # must build a valid Problem
    # deterministic: same seed, same distribution
    again = dsm.sample_load_cases(16, seed=3)
    assert [c.describe() for c in again] == [c.describe() for c in cases]


def test_load_case_json_roundtrip():
    for c in dsm.sample_load_cases(4, seed=1):
        assert dsm.LoadCase.from_dict(c.describe()) == c


# ----------------------------------------------- batched trajectory builds


def test_run_simp_b_matches_sequential_run_simp():
    """Dataset construction runs through the PR 1 batch machinery; each
    batched trajectory must match its standalone run_simp to fp32
    tolerance (training data has no bitwise contract)."""
    cases = dsm.sample_load_cases(3, seed=5)
    probs = [c.problem(12, 4) for c in cases]
    batched = dsm.run_simp_b(probs, n_iter=6)
    for p, hb in zip(probs, batched):
        _, hs = simp.run_simp(p, n_iter=6)
        np.testing.assert_allclose(hb["x"], hs["x"], atol=1e-2)
        scale = np.abs(hs["u"]).max()
        np.testing.assert_allclose(hb["u"] / scale, hs["u"] / scale,
                                   atol=1e-3)


def test_dataset_structure_and_trajectory_split():
    cfg = _tiny_cfg()
    cases = dsm.sample_load_cases(4, seed=2)
    ds = dsm.build_dataset(cfg, cases=cases, n_iter=8, batch=3)
    per_traj = 8 - cfg.hist_len
    assert ds.n_trajectories == 4
    assert ds.n_windows == 4 * per_traj
    assert ds.windows.shape == (ds.n_windows, cfg.hist_len,
                                cfg.nely, cfg.nelx, 1)
    assert ds.targets.shape == (ds.n_windows,
                                2 * (cfg.nelx + 1) * (cfg.nely + 1))
    # one shared u_scale normalizes the whole set
    assert np.abs(ds.targets).max() == pytest.approx(1.0)
    # every window row carries ITS trajectory's load conditioning
    for t, case in enumerate(cases):
        rows = ds.rows_of(t)
        assert len(rows) == per_traj
        lv = np.asarray(fea2d.load_volume(case.problem(cfg.nelx, cfg.nely)))
        for r in rows:
            np.testing.assert_array_equal(ds.load_vol[r], lv)
    # split is BY trajectory: no window of a held-out trajectory trains,
    # and the canonical case (trajectory 0) always stays in training
    train, held = dsm.split_by_trajectory(ds, heldout_frac=0.25, seed=0)
    assert len(held) >= 1 and 0 in train
    assert not set(train) & set(held)
    assert len(train) + len(held) == 4


def test_legacy_single_trajectory_dataset_still_works():
    """benchmarks/precision.py & examples pass the legacy 5-tuple; train
    must accept it and unpack as the legacy 4-tuple."""
    cfg = _tiny_cfg()
    data = train_cronet.build_dataset(cfg, n_iter=6)
    load_vol, windows, targets, u_scale, hist = data
    assert windows.shape[0] == 6 - cfg.hist_len
    res = train_cronet.train(cfg, steps=2, data=data, verbose=False)
    params, us, losses, ref = res
    assert us == u_scale and len(losses) == 2
    assert res.eval_metrics["train_trajectories"] == 1


# ----------------------------------------------------------------- registry


@pytest.fixture(scope="module")
def tiny_params():
    cfg = _tiny_cfg()
    return materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(7))


def test_registry_register_get_latest_load(tmp_path, tiny_params):
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    with pytest.raises(NoModelError):
        reg.load()
    with pytest.raises(NoModelError):
        reg.get("nope")
    rec = reg.register(tiny_params, cfg, 42.0, tag="a",
                       metrics={"acceptance": 0.5},
                       load_cases=[dsm.MBB_CASE.describe()])
    reg.register(tiny_params, cfg, 43.0)        # auto tag v2
    assert reg.tags() == ["a", "v2"]
    assert reg.latest().tag == "v2"
    got = reg.get("a")
    assert got.u_scale == 42.0 and got.version == 1
    assert got.metrics["acceptance"] == 0.5
    assert got.cfg == cfg                       # cfg round-trips the json
    params, rec2 = reg.load("a")
    assert rec2.tag == "a"
    for x, y in zip(jax.tree.leaves(tiny_params), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError, match="already registered"):
        reg.register(tiny_params, cfg, 1.0, tag="a")


def test_registry_prune_respects_pins(tmp_path, tiny_params):
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    for i in range(5):
        reg.register(tiny_params, cfg, float(i), tag=f"m{i}",
                     pin=(i == 1))
    dropped = reg.prune(keep=2)
    assert dropped == ["m0", "m2"]              # m1 pinned, m3/m4 newest
    assert reg.tags() == ["m1", "m3", "m4"]
    reg.load("m1")                              # pinned stays restorable
    reg.pin("m1", pinned=False)
    assert reg.prune(keep=2) == ["m1"]


def test_registry_latest_tie_breaks_mesh_specialized_tags(tmp_path,
                                                          tiny_params):
    """A mesh-specialized fine-tune must never hijack the fleet default:
    latest() skips specialized versions; latest(mesh=...) finds exactly
    its mesh's newest specialization."""
    from repro.serve import ModelResolver

    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    reg.register(tiny_params, cfg, 1.0, tag="fleet1")
    reg.register(tiny_params, cfg, 2.0, tag="spec-a", mesh=(12, 4))
    assert reg.latest().tag == "fleet1"       # specialized did not win
    assert reg.latest(mesh=(12, 4)).tag == "spec-a"
    assert reg.latest(mesh=(10, 6)) is None
    reg.register(tiny_params, cfg, 3.0, tag="spec-b", mesh=(12, 4))
    reg.register(tiny_params, cfg, 4.0, tag="fleet2")
    assert reg.latest().tag == "fleet2"
    assert reg.latest(mesh=(12, 4)).tag == "spec-b"   # newest of ITS mesh
    assert reg.get("spec-a").mesh == (12, 4)          # json round-trips
    # the resolver packages the bucket lookup: specialized > default
    res = ModelResolver(reg, default_tag="fleet1")
    assert res.resolve((12, 4)).tag == "spec-b"
    assert res.resolve((10, 6)).tag == "fleet1"
    res.default_tag = None
    assert res.resolve((10, 6)).tag == "fleet2"       # falls to latest()


def test_registry_prune_defers_served_and_canaried_versions(tmp_path,
                                                            tiny_params):
    """prune() must never delete a LIVE version: tags leased by a
    serving gateway (its fleet default at construction, a canary from
    the moment the experiment starts) are deferred until released —
    even unpinned ones — and become reclaimable afterwards."""
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    for i in range(4):
        reg.register(tiny_params, cfg, float(i), tag=f"m{i}")
    reg.acquire("m1")                             # direct lease
    dropped = reg.prune(keep=1)
    assert set(dropped) == {"m0", "m2"}           # m1 live, m3 newest
    reg.load("m1")                                # still restorable
    # a gateway leases its serving tag for its whole lifetime, and a
    # canaried tag from canary() on — no engine has to exist yet
    gw = TopoGateway.from_registry(reg, tag="m1",
                                   engine_factory=lambda x, y: None)
    gw.canary("m3", fraction=0.5, mesh=(12, 4), auto_rollback=False)
    assert reg.leased() == {"m1": 2, "m3": 1}
    assert reg.prune(keep=0) == []                # everything live
    gw.rollback(mesh=(12, 4), timeout=10)         # canary lease released
    assert reg.leased() == {"m1": 2}
    assert reg.prune(keep=0) == ["m3"]            # m1 still deferred
    gw.shutdown()                                 # gateway lease released
    assert reg.leased() == {"m1": 1}
    reg.release("m1")
    assert reg.leased() == {}
    assert reg.prune(keep=0) == ["m1"]
    with pytest.raises(NoModelError):
        reg.load("m1")


def test_registry_promote_stamps_promotion_metadata(tmp_path,
                                                    tiny_params):
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    reg.register(tiny_params, cfg, 1.0, tag="a")
    assert reg.get("a").promoted_at is None
    first = reg.promote("a").promoted_at
    assert first
    assert reg.promote("a").promoted_at == first   # idempotent
    with pytest.raises(NoModelError):
        reg.promote("ghost")


def test_resolver_cache_invalidated_on_reregister(tmp_path, tiny_params):
    """Regression: the resolver's per-tag param cache used to survive a
    prune + re-register of the same tag, silently serving the DELETED
    version's params. Every index write bumps the registry generation;
    a stale-generation cache is dropped before any hit."""
    import jax

    from repro.serve import ModelResolver

    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    reg.register(tiny_params, cfg, 1.0, tag="a")
    res = ModelResolver(reg)
    p1, rec1 = res.load("a")
    assert rec1.u_scale == 1.0
    assert reg.prune(keep=0) == ["a"]
    params2 = jax.tree.map(lambda x: x + 1.0, tiny_params)
    reg.register(params2, cfg, 2.0, tag="a")       # same tag, new params
    p2, rec2 = res.load("a")
    assert rec2.u_scale == 2.0
    for x, y in zip(jax.tree.leaves(params2), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # untouched registry: the cache still serves hits (no thrash)
    assert res.load("a")[1].u_scale == 2.0
    assert res.load("a")[1] is rec2


# --------------------------------------- the trained-surrogate fixture


@pytest.fixture(scope="module")
def lifecycle(tmp_path_factory):
    """One shared training pass: a multi-load-case surrogate and the
    single-MBB-trajectory baseline, both registered in one registry.
    Module-scoped — this is the expensive part of the suite."""
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path_factory.mktemp("registry")))
    multi_data = dsm.build_dataset(
        cfg, cases=dsm.sample_load_cases(12, seed=0, max_angle_deg=30.0),
        n_iter=30)
    single_data = train_cronet.build_dataset(cfg, n_iter=30)
    multi_rec, multi_res = train_cronet.train_and_register(
        cfg, reg, tag="multi", data=multi_data, steps=600, verbose=False,
        heldout_frac=0.25, error_threshold=THRESHOLD)
    single_rec, single_res = train_cronet.train_and_register(
        cfg, reg, tag="single", data=single_data, steps=600, verbose=False)
    return {"cfg": cfg, "registry": reg,
            "multi": multi_rec, "single": single_rec,
            "multi_result": multi_res, "single_result": single_res}


def _serve_hit_rate(cfg, params, u_scale, probs, n_iter=20,
                    model_tag=None):
    """Serve the problems through the real engine; return the pooled
    CRONet hit rate and the per-request densities."""
    eng = TopoServingEngine(cfg, params, u_scale, slots=2,
                            precision="fp32", error_threshold=THRESHOLD,
                            model_tag=model_tag)
    done = eng.run([TopoRequest(uid=i, problem=p, n_iter=n_iter)
                    for i, p in enumerate(probs)])
    eng.shutdown()
    stats = eng.throughput_stats(done)
    return stats["cronet_hit_rate"], done


def test_multi_load_case_surrogate_beats_single_trajectory_baseline(
        lifecycle):
    """THE subsystem claim: on held-out off-distribution point loads the
    single-trajectory baseline's hit rate is exactly 0% (every request
    is pure FEA fallback) while the multi-load-case surrogate's NN path
    actually fires."""
    cfg, reg = lifecycle["cfg"], lifecycle["registry"]
    probs = _held_out_loads(cfg)
    m_params, m_rec = reg.load("multi")
    s_params, s_rec = reg.load("single")
    multi_hit, multi_done = _serve_hit_rate(
        cfg, m_params, m_rec.u_scale, probs, model_tag="multi")
    single_hit, _ = _serve_hit_rate(
        cfg, s_params, s_rec.u_scale, probs, model_tag="single")
    assert single_hit == 0.0, (
        f"single-trajectory baseline unexpectedly accepted "
        f"{single_hit:.0%} on off-distribution loads")
    assert multi_hit > 0.0, (
        "multi-load-case surrogate never accepted on held-out loads — "
        "the NN path still does not fire in serving")
    assert all(r.model_tag == "multi" for r in multi_done)
    # the registry recorded the generalization evidence
    assert lifecycle["multi"].metrics["acceptance"] >= 0.0
    assert len(lifecycle["multi"].load_cases) == 12


def test_slot_invariance_holds_with_registry_loaded_params(lifecycle):
    """Bitwise slot-invariance contract, now through the registry: a
    round-tripped checkpoint served in a batch slot must equal the
    standalone run_hybrid of the SAME round-tripped params bit for bit
    (restore is bitwise, so this guards both restore and serving)."""
    cfg, reg = lifecycle["cfg"], lifecycle["registry"]
    params, rec = reg.load("multi")
    probs = _held_out_loads(cfg, n=3, seed=123)
    seq = [hybrid.run_hybrid(cfg, params, rec.u_scale, n_iter=8,
                             precision="fp32", problem=p,
                             compute_metrics=False,
                             error_threshold=THRESHOLD) for p in probs]
    eng = TopoServingEngine(cfg, params, rec.u_scale, slots=2,
                            precision="fp32", error_threshold=THRESHOLD)
    done = eng.run([TopoRequest(uid=i, problem=p, n_iter=8)
                    for i, p in enumerate(probs)])
    eng.shutdown()
    for r, s in zip(done, seq):
        np.testing.assert_array_equal(r.density, s.density,
                                      err_msg=f"request {r.uid}")
        assert r.cronet_iters == s.cronet_invocations


def test_gateway_swap_model_drops_nothing(lifecycle):
    """swap_model mid-backlog: every queued/in-flight request completes
    (zero dropped, zero failed), requests finishing after the swap carry
    the new tag, and the stats are labelled."""
    cfg, reg = lifecycle["cfg"], lifecycle["registry"]
    gw = TopoGateway.from_registry(reg, tag="single", slots=2,
                                   precision="fp32",
                                   error_threshold=THRESHOLD)
    assert gw.model_tag == "single"
    probs = _held_out_loads(cfg, n=6, seed=11)
    futs = [gw.submit(TopoRequest(uid=i, problem=p, n_iter=6))
            for i, p in enumerate(probs)]
    new_tag = gw.swap_model("multi")
    assert new_tag == "multi"
    done = [f.result(timeout=600) for f in futs]
    assert all(r.done for r in done)
    assert all(f.exception() is None for f in futs), \
        "swap_model failed in-flight futures"
    post = gw.submit(TopoRequest(uid=99, problem=probs[0], n_iter=6))
    assert post.result(timeout=600).model_tag == "multi"
    stats = gw.throughput_stats()
    assert stats["model_tag"] == "multi"
    assert stats["model_swaps"] == 1.0
    assert "multi" in stats["model_tags"]
    gw.shutdown()


def test_swap_model_rejects_incompatible_architecture(tmp_path,
                                                      tiny_params):
    """A checkpoint trained under a different architecture (e.g. another
    hist_len) must be rejected BEFORE any bucket drains — the buckets'
    compiled steps are shaped by the gateway's cfg."""
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    reg.register(tiny_params, cfg, 50.0, tag="ok")
    reg.register(tiny_params, dataclasses.replace(cfg, hist_len=5), 50.0,
                 tag="alien")
    gw = TopoGateway.from_registry(reg, tag="ok", slots=2,
                                   precision="fp32")
    with pytest.raises(ValueError, match="incompatible config"):
        gw.swap_model("alien")
    assert gw.model_tag == "ok"        # old model still the served one
    gw.shutdown()


def test_engine_swap_params_requires_quiescence(lifecycle):
    cfg, reg = lifecycle["cfg"], lifecycle["registry"]
    params, rec = reg.load("multi")
    eng = TopoServingEngine(cfg, params, rec.u_scale, slots=2,
                            precision="fp32")
    fut = eng.submit(TopoRequest(uid=0, problem=_held_out_loads(cfg, 1)[0],
                                 n_iter=4))
    with pytest.raises(RuntimeError, match="running engine"):
        eng.swap_params(params)
    fut.result(timeout=600)
    eng.stop()
    eng.swap_params(params, model_tag="multi-again")   # quiescent: fine
    assert eng.model_tag == "multi-again"
    eng.shutdown()


# ------------------------------------------------------------- slow tier


@pytest.mark.slow
def test_full_multi_load_case_training_hits_30_percent(tmp_path):
    """Nightly full-budget run: the production-shaped training
    configuration must push the off-distribution CRONet hit rate to
    >= 30% — the operating point where the paper's latency win survives
    the serving distribution.

    Configuration notes (measured on the dev container): coverage
    density is the lever that kills seed variance — at 32 training
    cases, hit rates ranged 20-38% across seeds with whole held-out
    loads never accepting; at 64 cases every seed/noise variant landed
    33-43% with EVERY held-out load accepting. noise=0.03 (density
    jitter toward the hybrid loop's drifted trajectories) gave the best
    single point (43%)."""
    cfg = _tiny_cfg()
    reg = ModelRegistry(str(tmp_path))
    data = dsm.build_dataset(
        cfg, cases=dsm.sample_load_cases(64, seed=0, max_angle_deg=30.0),
        n_iter=30, batch=16)
    rec, res = train_cronet.train_and_register(
        cfg, reg, tag="full", data=data, steps=2000, batch=32,
        noise=0.03, verbose=False, heldout_frac=0.1,
        error_threshold=THRESHOLD)
    params, rec = reg.load("full")
    probs = _held_out_loads(cfg, n=6)
    hit, done = _serve_hit_rate(cfg, params, rec.u_scale, probs, n_iter=20,
                                model_tag="full")
    assert all(r.cronet_iters > 0 for r in done), (
        "a held-out load never accepted the surrogate: "
        f"{[r.cronet_iters for r in done]}")
    assert hit >= 0.30, (
        f"full-budget multi-load-case surrogate hit rate {hit:.0%} < 30% "
        f"on off-distribution loads")
