"""Observability-layer contracts (repro.obs):

  * histogram bucket estimates bracket the EXACT sample percentiles
    (property-based over random sample sets);
  * trace span timelines are contiguous by construction — phase
    durations sum exactly to end-to-end latency;
  * tracing is bitwise-invisible to serving, through forced
    preemption/park/restore cycles and canary routing;
  * telemetry snapshots tolerate torn trailing lines (crash mid-write)
    and enforce newest-N retention;
  * concurrent metric / fleet-event recording loses no updates
    (property-based thread interleavings);
  * ``FleetEvent.t_mono`` is populated everywhere and ``fleet_events``
    sorts on it; ``TopoRequest.admitted_t`` recovers queue age.
"""
import dataclasses
import json
import random
import threading
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, Trace,
                       TelemetrySnapshotter, default_registry,
                       exponential_buckets, read_snapshots,
                       set_default_registry)
from repro.obs import dashboard
from repro.obs import trace as obs_trace

U_SCALE = 50.0


# ------------------------------------------------------------- metrics


def test_exponential_buckets_strictly_increasing():
    b = exponential_buckets(1e-4, 2.0, 21)
    assert len(b) == 21
    assert all(b[i] < b[i + 1] for i in range(len(b) - 1))
    assert b[1] / b[0] == pytest.approx(2.0)
    with pytest.raises(ValueError):
        Histogram("h", buckets=[1.0, 1.0, 2.0])


def test_counter_labels_and_totals():
    c = Counter("reqs")
    c.inc()
    c.inc(2, mesh="12x4")
    c.inc(3, mesh="12x4")
    c.inc(mesh="10x6")
    assert c.value() == 1.0
    assert c.value(mesh="12x4") == 5.0
    assert c.total() == 7.0
    # label VALUES are stringified, so 4 and "4" are the same series
    c.inc(rung=4)
    c.inc(rung="4")
    assert c.value(rung=4) == 2.0


def test_gauge_callback_sampled_at_read_and_exception_safe():
    box = {"v": 3.0}
    g = Gauge("depth", callback=lambda: box["v"])
    assert g.value() == 3.0
    box["v"] = 7.0
    assert g.value() == 7.0          # sampled at read, not registration
    bad = Gauge("bad", callback=lambda: 1 / 0)
    assert np.isnan(bad.value())     # a broken hook must not raise
    s = Gauge("set")
    s.set(2.0, mesh="12x4")
    s.inc(1.0, mesh="12x4")
    assert s.value(mesh="12x4") == 3.0


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("x", "help")
    assert reg.counter("x") is c1
    with pytest.raises(TypeError):
        reg.histogram("x")
    # default-registry swap is how tests/benchmarks isolate themselves
    prev = set_default_registry(reg)
    try:
        assert default_registry() is reg
    finally:
        set_default_registry(prev)
    assert default_registry() is prev


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_histogram_percentiles_bracket_exact_values(seed):
    """The bucket estimate must land inside the bucket CONTAINING the
    exact percentile — bucket-width accuracy is the contract (fixed
    buckets, no per-observation allocation), not exactness."""
    rng = random.Random(seed)
    h = Histogram("lat", buckets=exponential_buckets(1e-4, 2.0, 21))
    samples = [rng.lognormvariate(-4.0, 1.5) for _ in range(500)]
    for v in samples:
        h.observe(v)
    bounds = (0.0,) + h.bounds
    for q in (50.0, 90.0, 99.0):
        exact = float(np.percentile(samples, q,
                                    method="inverted_cdf"))
        est = h.percentile(q)
        # locate the bucket holding the exact value: est must be in it
        i = next(k for k in range(len(bounds) - 1)
                 if exact <= bounds[k + 1]) if exact <= bounds[-1] \
            else len(bounds) - 2
        lo, hi = bounds[i], bounds[i + 1]
        assert lo <= est <= hi, \
            (q, exact, est, lo, hi)


def test_histogram_aggregates_across_labelsets_without_labels():
    h = Histogram("t", buckets=[1.0, 10.0, 100.0])
    h.observe(0.5, n=3, mesh="a")
    h.observe(50.0, mesh="b")
    assert h.count() == 4 and h.count(mesh="a") == 3
    assert h.sum() == pytest.approx(51.5)
    assert h.percentile(50.0) <= 1.0       # 3 of 4 obs in first bucket
    assert h.percentile(99.0) > 10.0


def test_prometheus_exposition_cumulative_buckets():
    reg = MetricsRegistry()
    reg.counter("c_total", "a counter").inc(2, mesh="12x4")
    h = reg.histogram("h_s", "a histogram", buckets=[1.0, 10.0])
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    text = reg.to_prometheus()
    assert "# TYPE c_total counter" in text
    assert 'c_total{mesh="12x4"} 2' in text
    # le buckets are CUMULATIVE and +Inf equals _count
    assert 'h_s_bucket{le="1"} 1' in text
    assert 'h_s_bucket{le="10"} 2' in text
    assert 'h_s_bucket{le="+Inf"} 3' in text
    assert "h_s_count 3" in text
    # snapshot mirrors the same series
    snap = reg.snapshot()
    assert snap["c_total"]["kind"] == "counter"
    assert snap["h_s"]["kind"] == "histogram"


# --------------------------------------------------------------- traces


def test_trace_spans_tile_end_to_end_exactly():
    """begin() closes the open span at the SAME stamp, so the phases
    tile submit -> done with zero gap — sum equals e2e exactly, not
    within tolerance."""
    tr = Trace(uid=7)
    tr.begin(obs_trace.QUEUED, t=100.0)
    tr.begin(obs_trace.COMPUTE, t=101.5, lane=0)
    tr.begin(obs_trace.PARKED, t=103.0, iters_done=3)
    tr.begin(obs_trace.COMPUTE, t=110.0, lane=1)
    tr.finish(t=112.25, iters=6)
    assert tr.complete
    phases = tr.phase_durations()
    assert phases == {"queued": 1.5, "compute": 1.5 + 2.25,
                      "parked": 7.0}
    assert sum(phases.values()) == tr.end_to_end_s() == 12.25
    assert tr.total_s() == tr.end_to_end_s()
    assert tr.preemption_cycles() == 1
    d = tr.to_dict()
    assert d["complete"] and len(d["spans"]) == 4
    assert "compute" in tr.render()


def test_trace_bounded_spans_and_split_accounting():
    tr = Trace(uid=1, max_spans=4)
    for k in range(10):
        tr.begin("compute", t=float(k))
    tr.finish(t=10.0)
    assert len(tr.spans) == 4 and tr.dropped_spans == 6
    tr.window(1.0, 2, 1, 1, 30)
    tr.window(2.0, 3, 0, 3, 90)
    assert tr.cronet_split() == {"cronet_iters": 1, "fea_iters": 4,
                                 "cg_iters": 120}
    tr.tick(0.5, 4, 1)
    assert list(tr.ticks) == [(0.5, 4, 1)]


# ------------------------------------------------------------ exporters


def test_snapshotter_torn_line_tolerance_and_retention(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(5)
    path = str(tmp_path / "telemetry.jsonl")
    snap = TelemetrySnapshotter(path, registry=reg, interval_s=60.0,
                                max_snapshots=3)
    for _ in range(5):
        snap.snapshot_once()
    with open(path) as f:
        assert len(f.readlines()) == 3       # newest-N retention
    # crash mid-append: a torn trailing line must not poison readers
    with open(path, "a") as f:
        f.write('{"t": 1.0, "metrics": {"c": {"kin')
    recs = read_snapshots(path)
    assert len(recs) == 3
    assert all(r["metrics"]["c"]["kind"] == "counter" for r in recs)
    # the prom file rides along
    with open(snap.prom_path) as f:
        assert "# TYPE c counter" in f.read()


def test_snapshotter_extra_hook_failure_is_recorded(tmp_path):
    snap = TelemetrySnapshotter(str(tmp_path / "t.jsonl"),
                                registry=MetricsRegistry(),
                                extra=lambda: 1 / 0)
    rec = snap.snapshot_once()
    assert "extra_error" in rec and "extra" not in rec


def test_snapshotter_stop_keeps_handle_while_daemon_is_wedged(tmp_path):
    """Regression: ``stop()`` used to clear ``self._thread`` even when
    the join timed out — a later ``start()`` then spawned a SECOND loop
    racing the wedged one onto the same files. The handle must survive
    a timed-out join (so start() stays a no-op) and clear only once the
    daemon really exited."""
    snap = TelemetrySnapshotter(str(tmp_path / "t.jsonl"),
                                registry=MetricsRegistry(),
                                interval_s=60.0)
    # clean path: the daemon honours the stop event within the join
    # window, the handle clears, and a restart is allowed
    snap.start()
    snap.stop(final_snapshot=False)
    assert snap._thread is None

    # wedged path: a thread that outlives join(timeout) — simulated by
    # a stub handle, exactly what stop() inspects — must be KEPT
    class _Wedged:
        def __init__(self, alive):
            self.alive = alive
            self.joins = 0

        def join(self, timeout=None):
            self.joins += 1

        def is_alive(self):
            return self.alive

    wedged = _Wedged(alive=True)
    snap._stop.clear()
    snap._thread = wedged
    snap.stop(final_snapshot=True)
    assert snap._thread is wedged, "timed-out join must keep the handle"
    assert wedged.joins == 1
    # while the handle survives, start() cannot spawn a second loop
    assert snap.start() is snap
    assert snap._thread is wedged
    # the final snapshot still landed (snapshot_once serializes writes
    # under the instance lock, so it is safe beside a wedged loop)
    assert snap.snapshots_written >= 1
    # once the daemon actually died, the next stop() releases the handle
    wedged.alive = False
    snap.stop(final_snapshot=False)
    assert snap._thread is None


class _StringIO:
    def __init__(self):
        self.parts = []

    def write(self, s):
        self.parts.append(s)

    def flush(self):
        pass

    def getvalue(self):
        return "".join(self.parts)


def test_dashboard_renders_stats_and_instruments():
    reg = MetricsRegistry()
    reg.counter("topo_completions_total").inc(3, mesh="12x4")
    reg.histogram("topo_tick_latency_s").observe(0.01, mesh="12x4")
    stats = {"requests": 3.0, "problems_per_s": 1.5,
             "cronet_hit_rate": 0.5, "p99_latency_s": 0.2,
             "per_mesh": {"12x4": {"requests": 3.0,
                                   "cronet_hit_rate": 0.5,
                                   "p99_latency_s": 0.2,
                                   "model_tag": "prod"}}}
    frame = dashboard.render(registry=reg, stats=stats)
    assert "12x4" in frame and "topo_tick_latency_s" in frame
    out = _StringIO()
    dashboard.watch(registry=reg, stats_fn=lambda: stats,
                    interval_s=0.01, frames=2, out=out)
    assert out.getvalue().count("repro.obs dashboard") == 2


# -------------------------------------------- concurrent recording


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 6),        # writer threads
       st.integers(0, 10 ** 6))  # interleaving seed
def test_concurrent_metric_recording_loses_nothing(n_threads, seed):
    """Counters/histograms take concurrent writers from every serving
    layer (shard loops, dispatcher, user threads): totals must be
    exact under arbitrary interleavings."""
    reg = MetricsRegistry()
    c = reg.counter("hits")
    h = reg.histogram("lat", buckets=exponential_buckets(1e-3, 4.0, 8))
    per = 200
    rng = random.Random(seed)
    stagger = [rng.random() * 1e-3 for _ in range(n_threads)]

    def work(k):
        time.sleep(stagger[k])
        for i in range(per):
            c.inc(mesh=f"m{k % 2}")
            h.observe(1e-3 * (i + 1), mesh=f"m{k % 2}")

    ts = [threading.Thread(target=work, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.total() == n_threads * per
    assert h.count() == n_threads * per
    assert h.sum() == pytest.approx(
        n_threads * sum(1e-3 * (i + 1) for i in range(per)))


# ------------------------------------- serving integration (real engines)


@pytest.fixture(scope="module")
def trained():
    import jax

    from repro.common import materialize
    from repro.configs.cronet import get_cronet_config
    from repro.core import cronet

    cfg = dataclasses.replace(get_cronet_config("small"),
                              nelx=12, nely=4, hist_len=3)
    params = materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
    return cfg, params


def _problems(n, nelx=12, nely=4):
    from repro.fea import fea2d
    return [fea2d.point_load_problem(nelx, nely,
                                     load_node=(i % (nelx - 1), 0),
                                     load=(0.0, -1.0 - 0.1 * i))
            for i in range(n)]


def test_tracing_bitwise_invisible_across_preemption(trained):
    """Force a park/restore cycle with tracing ON: densities stay
    bitwise-equal to the untraced run, the evicted request's trace
    carries a parked span, and every phase timeline tiles its measured
    end-to-end latency."""
    from repro.serve import TopoRequest, TopoServingEngine

    cfg, params = trained
    probs = _problems(3)

    def serve(trace_every):
        # tick_time_s pinned so the preemption decision is deterministic
        eng = TopoServingEngine(cfg, params, U_SCALE, slots=2,
                                precision="fp32", tick_time_s=10.0,
                                trace_every=trace_every)
        futs = [eng.submit(TopoRequest(uid=k, problem=probs[k],
                                       n_iter=10)) for k in range(2)]
        t0 = time.time()
        while any(a is None for a in eng._shards[0].slot_adm):
            assert time.time() - t0 < 60, "occupants never admitted"
            time.sleep(0.005)
        fut_u = eng.submit(TopoRequest(uid=9, problem=probs[2], n_iter=3),
                           deadline_s=35.0)
        done = [f.result(timeout=600) for f in futs]
        done.append(fut_u.result(timeout=600))
        traces = [eng.trace(r.uid) for r in done]
        parked = sum(r.preemptions for r in done)
        eng.shutdown()
        return done, traces, parked

    plain, none_traces, parked0 = serve(0)
    traced, traces, parked1 = serve(1)
    assert parked0 >= 1 and parked1 >= 1, "preemption never fired"
    assert all(t is None for t in none_traces)
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a.density, b.density,
                                      err_msg=f"uid {a.uid}")
    victim_spans = 0
    for r, tr in zip(traced, traces):
        assert tr is not None and tr.complete
        phases = tr.phase_durations()
        e2e = tr.end_to_end_s()
        assert abs(sum(phases.values()) - e2e) <= max(1e-6, 0.01 * e2e)
        # the span boundaries ARE the request's own stamps
        assert tr.submit_t == r.submit_t
        assert r.admitted_t is not None
        assert r.queue_wait_s == pytest.approx(r.admitted_t - r.submit_t)
        victim_spans += tr.preemption_cycles()
        assert tr.preemption_cycles() == r.preemptions
    assert victim_spans >= 1, "no trace recorded the park/restore cycle"


def test_tracing_bitwise_invisible_across_canary_routing(trained,
                                                         tmp_path):
    """Canary routing with tracing ON: the canary-vs-primary split and
    every density match a trace_every=0 gateway run of the same
    backlog; traces are registered at the gateway for BOTH tags."""
    from repro.serve import ModelRegistry, TopoGateway, TopoRequest

    cfg, params = trained
    reg = ModelRegistry(str(tmp_path))
    reg.register(params, cfg, U_SCALE, tag="prod")
    # same params under a distinct tag: routing must SPLIT tags while
    # densities stay comparable across the traced/untraced runs
    reg.register(params, cfg, U_SCALE, tag="cand")
    probs = _problems(4)

    def serve(trace_every):
        gw = TopoGateway.from_registry(reg, tag="prod", slots=2,
                                       trace_every=trace_every)
        warm = gw.submit(TopoRequest(uid=-1, problem=probs[0], n_iter=2))
        warm.result(timeout=600)
        gw.canary("cand", fraction=0.5, mesh=(12, 4),
                  auto_rollback=False)
        futs = [gw.submit(TopoRequest(uid=i, problem=p, n_iter=4))
                for i, p in enumerate(probs)]
        done = [f.result(timeout=600) for f in futs]
        traces = [gw.trace(r.uid) for r in done]
        events = gw.fleet_events()
        gw.shutdown()
        return done, traces, events

    plain, none_traces, _ = serve(0)
    traced, traces, events = serve(1)
    assert all(t is None for t in none_traces)
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a.density, b.density,
                                      err_msg=f"uid {a.uid}")
        assert a.routed_tag == b.routed_tag
    routed = {r.routed_tag for r in traced}
    assert len(routed) == 2, f"canary routing never split: {routed}"
    for r, tr in zip(traced, traces):
        assert tr is not None and tr.complete, f"uid {r.uid}"
        e2e = tr.end_to_end_s()
        assert abs(sum(tr.phase_durations().values()) - e2e) \
            <= max(1e-6, 0.01 * e2e)
    # FleetEvent.t_mono is populated and fleet_events sorts on it
    assert events and all(e.t_mono > 0.0 for e in events)
    assert [e.t_mono for e in events] == sorted(e.t_mono for e in events)
    assert any(e.kind == "canary-start" for e in events)
