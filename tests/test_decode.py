"""Serving correctness: prefill + decode must reproduce the full-forward
logits at the last position (fp32, all decoder archs)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.common import materialize
from repro.configs.all import ASSIGNED
from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import model as M
from repro.serve import decode as D

B, S = 2, 8


@pytest.mark.parametrize("name", [a for a in ASSIGNED
                                  if get_config(a).has_decode])
def test_prefill_decode_matches_forward(name):
    cfg = dataclasses.replace(get_config(name).reduce(), dtype="float32")
    s = S if cfg.family != "vlm" else cfg.frontend_tokens + S
    params = materialize(M.param_specs(cfg), jax.random.key(0))
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, B, s).next_batch().items()}
    batch.pop("labels")
    full, _ = M.forward(cfg, params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    lg_pre, cache = D.prefill(cfg, params, pre, max_len=s + 4)
    lg_dec, cache2 = D.decode_step(cfg, params, batch["tokens"][:, -1:], cache)

    tol = 2e-3 if cfg.family in ("hybrid", "ssm", "moe") else 1e-4
    diff = float(jnp.max(jnp.abs(full[:, -1].astype(jnp.float32)
                                 - lg_dec[:, 0].astype(jnp.float32))))
    assert diff < tol, f"{name}: decode diverges from forward by {diff}"
    assert int(cache2["index"]) == s


@pytest.mark.parametrize("name", ["qwen2.5-32b", "recurrentgemma-2b",
                                  "xlstm-1.3b"])
def test_multi_token_generation(name):
    """Greedy generation for 4 steps is deterministic and finite."""
    cfg = dataclasses.replace(get_config(name).reduce(), dtype="float32")
    params = materialize(M.param_specs(cfg), jax.random.key(0))
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, B, S).next_batch().items()}
    batch.pop("labels")
    _, cache = D.prefill(cfg, params, batch, max_len=S + 8)
    tok = batch["tokens"][:, -1:]
    outs = []
    for _ in range(4):
        lg, cache = D.decode_step(cfg, params, tok, cache)
        tok = jnp.argmax(lg[:, -1:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
        outs.append(tok)
    assert int(cache["index"]) == S + 4


def test_rolling_window_cache_decode_long():
    """Hybrid arch: decode far past the window — cache stays window-sized
    and logits stay finite (the long_500k mechanism)."""
    cfg = dataclasses.replace(get_config("recurrentgemma-2b").reduce(),
                              dtype="float32", attn_window=4)
    params = materialize(M.param_specs(cfg), jax.random.key(0))
    batch = {"tokens": jnp.ones((1, 6), jnp.int32)}
    _, cache = D.prefill(cfg, params, batch, max_len=6)
    assert cache["k"].shape[2] == 4  # window-sized, not seq-sized
    tok = jnp.ones((1, 1), jnp.int32)
    for _ in range(8):  # run well past the window
        lg, cache = D.decode_step(cfg, params, tok, cache)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    assert int(cache["index"]) == 14
