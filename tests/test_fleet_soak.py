"""Fleet-operations chaos/soak (slow tier, nightly): mixed-mesh Poisson
traffic with canary swaps, forced rollbacks, fleet model swaps, and
cold-mesh eviction all firing MID-STREAM for several cycles against real
engines.

The invariants this locks down (the fleet layer's "nothing leaks"
contract):

  * zero futures leak — every submit resolves (completed; the queue is
    unbounded here so nothing is shed);
  * zero mis-tags — every completion's ``model_tag`` is the tag of the
    engine that served it (``routed_tag``);
  * engine THREAD count returns to baseline after each eviction wave
    (evicted engines' tick loops exit; only the dispatcher survives);
  * stats totals balance across evictions/rollbacks/promotions — the
    gateway's aggregate ``requests`` equals the number of completions,
    retired engine history included.
"""
import dataclasses
import random
import threading
import time

import jax
import pytest

from repro.common import materialize
from repro.configs.cronet import get_cronet_config
from repro.core import cronet
from repro.fea import fea2d
from repro.serve import ModelRegistry, TopoGateway, TopoRequest

MESHES = [(12, 4), (10, 6)]


def _serving_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith(("topo-shard", "topo-gateway"))]


def _wait(cond, timeout, what):
    t0 = time.time()
    while not cond():
        assert time.time() - t0 < timeout, f"timed out waiting for {what}"
        time.sleep(0.02)


@pytest.mark.slow
def test_fleet_soak_canary_rollback_eviction_cycles(tmp_path):
    cfg = dataclasses.replace(get_cronet_config("small"),
                              nelx=12, nely=4, hist_len=3)
    params = materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
    reg = ModelRegistry(str(tmp_path))
    reg.register(params, cfg, 50.0, tag="prod")
    reg.register(params, cfg, 50.0, tag="prod2")
    n_cycles = 3
    for c in range(n_cycles):
        reg.register(params, cfg, 50.0, tag=f"cand-{c}")

    pools = {m: [fea2d.point_load_problem(
        m[0], m[1], load_node=(i % (m[0] - 1), 0),
        load=(0.0, -1.0 - 0.1 * i)) for i in range(4)] for m in MESHES}
    assert _serving_threads() == []
    gw = TopoGateway.from_registry(reg, tag="prod", slots=2,
                                   max_pending=None, idle_evict_s=0.6)
    rng = random.Random(42)
    futs = []
    uid = 0
    for cycle in range(n_cycles):
        # -- Poisson-ish mixed-mesh arrivals, canary started mid-stream
        cycle_futs = []
        for i in range(12):
            m = MESHES[rng.randrange(len(MESHES))]
            f = gw.submit(
                TopoRequest(uid=uid, problem=pools[m][rng.randrange(4)],
                            n_iter=rng.randint(3, 6)),
                deadline_s=rng.choice([None, 10.0, 60.0]),
                priority=rng.choice([0, 0, 0, 1]))
            cycle_futs.append(f)
            uid += 1
            if i == 4:
                gw.canary(f"cand-{cycle}", fraction=0.4, mesh=(12, 4),
                          auto_rollback=False)
            time.sleep(rng.random() * 0.05)
        # -- end the experiment mid-stream: promote on even cycles,
        # forced rollback on odd ones (both drain, neither drops)
        if cycle % 2 == 0:
            assert gw.promote(mesh=(12, 4),
                              timeout=600) == [f"cand-{cycle}"]
        else:
            assert gw.rollback(mesh=(12, 4),
                               timeout=600) == [f"cand-{cycle}"]
        for f in cycle_futs:
            r = f.result(timeout=900)
            assert r.done
            assert r.model_tag == r.routed_tag, \
                (r.uid, r.model_tag, r.routed_tag)
        futs.extend(cycle_futs)
        # -- cold horizon: every bucket evicts, tick-loop threads exit,
        # only the dispatcher survives
        _wait(lambda: len(gw.engines) == 0, 60,
              f"cycle {cycle} eviction")
        _wait(lambda: len(_serving_threads()) == 1, 60,
              f"cycle {cycle} thread baseline")
        # -- fleet swap on the (now empty) pool: pending-tag semantics,
        # next cycle rebuilds on the swapped default
        tag = "prod2" if cycle % 2 == 0 else "prod"
        assert gw.swap_model(tag, timeout=600) == tag
    # -- totals balance: nothing leaked, nothing double-counted
    assert all(f.done() for f in futs)
    assert all(f.exception() is None for f in futs)
    stats = gw.throughput_stats()
    assert stats["requests"] == float(len(futs)), stats
    assert stats["evictions"] >= 2.0 * n_cycles     # both meshes, each cycle
    assert stats["rebuilds"] >= 2.0 * (n_cycles - 1)
    assert stats["promotions"] == float((n_cycles + 1) // 2)
    assert stats["rollbacks"] == float(n_cycles // 2)
    assert stats["shed"] == 0.0 and stats["rejected"] == 0.0
    # leases balance: only the current fleet default stays live
    gw.shutdown()
    assert reg.leased() == {}, reg.leased()
    assert _serving_threads() == []
