"""Placement pass + optimizer + compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import SHAPES, get_config
from repro.configs.cronet import get_cronet_config
from repro.core import placement
from repro.optim import adamw, compress

# ------------------------------------------------------------- placement


def test_congestion_aware_beats_default():
    """Paper Table VI, TPU currency: custom placement must cut bytes x hops
    vs the default (row-major) and random placers."""
    cfg = get_cronet_config("medium")
    nodes, edges = placement.cronet_graph(cfg)
    grid = (8, 38)
    c_row = placement.congestion_cost(placement.place_rowmajor(nodes, grid), edges)
    c_rand = placement.congestion_cost(placement.place_random(nodes, grid), edges)
    c_custom = placement.congestion_cost(
        placement.place_congestion_aware(nodes, edges, grid), edges)
    assert c_custom < c_row
    assert c_custom < c_rand
    assert c_custom < 0.6 * c_row   # substantial, not marginal


def test_placement_uses_disjoint_tiles():
    cfg = get_cronet_config("medium")
    nodes, edges = placement.cronet_graph(cfg)
    placed = placement.place_congestion_aware(nodes, edges, (8, 38))
    all_tiles = [t for ts in placed.values() for t in ts]
    assert len(all_tiles) == len(set(all_tiles))
    assert len(all_tiles) == sum(n.tiles for n in nodes) == 223  # Table IV


def test_rule_selection_runs():
    cfg = get_config("qwen2.5-32b")
    name, rules, report, all_reports = placement.choose_rules(
        cfg, SHAPES["train_4k"], {"data": 16, "model": 16})
    assert name in all_reports
    assert report.cost == min(r.cost for r in all_reports.values())
    assert report.cost > 0


def test_traffic_model_moe_has_a2a():
    cfg = get_config("deepseek-v3-671b")
    rep = placement.estimate_traffic(cfg, SHAPES["train_4k"],
                                     {"data": 16, "model": 16},
                                     placement.DEFAULT_RULES)
    assert rep.detail.get("moe_all_to_all", 0) > 0


# ------------------------------------------------------------- optimizer


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(cfg, params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}          # d/dw w^2
        params, state, _ = adamw.apply_updates(cfg, params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip_applied():
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(cfg, params)
    _, _, metrics = adamw.apply_updates(
        cfg, params, {"w": jnp.asarray([100.0, 0, 0])}, state)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0, rel=1e-3)     # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)    # min_lr_frac
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decays


# ------------------------------------------------------------- compression


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_ef_int8_identity_property(seed):
    """deq + residual == compensated input (error feedback loses nothing)."""
    x = jax.random.normal(jax.random.key(seed), (64,), jnp.float32)
    e0 = jnp.zeros_like(x)
    deq, e1 = compress.ef_compress_grads({"g": x}, {"g": e0})
    np.testing.assert_allclose(np.asarray(deq["g"] + e1["g"]),
                               np.asarray(x), rtol=1e-5, atol=1e-6)


def test_ef_int8_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1024,), jnp.float32) * 3
    deq, e = compress.ef_compress_grads({"g": x}, {"g": jnp.zeros_like(x)})
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(e["g"]))) <= amax / 127.0 + 1e-6


def test_ef_accumulates_small_signals():
    """A gradient below one quantization step must not be lost forever —
    error feedback accumulates it until it crosses a step."""
    big = jnp.asarray([127.0] + [0.0] * 7)
    small = jnp.asarray([127.0] + [0.3] * 7)   # 0.3 < step=1.0
    e = {"g": jnp.zeros(8)}
    total = jnp.zeros(8)
    for _ in range(10):
        deq, e = compress.ef_compress_grads({"g": small}, e)
        total = total + deq["g"]
    # after 10 steps the small signal must be substantially transmitted
    assert float(total[1]) > 0.3 * 10 * 0.5
