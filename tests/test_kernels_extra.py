"""Flash attention, fused sLSTM, chunkwise mLSTM — the beyond-paper Pallas
kernels, validated against oracles (§Perf iterations P4/X1/X2) — plus the
CRONet megakernel's batch grid dimension."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.cronet import get_cronet_config
from repro.common import materialize
from repro.core import cronet
from repro.kernels.cronet_pipeline import cronet_fused
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_causal_gqa)
from repro.kernels.slstm import slstm_fused
from repro.models import layers as L
from repro.models import model as M
from repro.models import recurrent as REC


def test_cronet_megakernel_batch_grid():
    """B>1 cronet_pipeline (one grid step per batch slot, weights resident
    across the batch) == batched core.cronet.forward, interpret mode."""
    B = 3
    cfg = dataclasses.replace(get_cronet_config("small"), dtype="float32")
    params = materialize(cronet.param_specs(cfg), jax.random.key(1))
    lv = jax.random.normal(jax.random.key(2),
                           (B, 4, cfg.nely + 1, cfg.nelx + 1, 1),
                           jnp.float32) * 0.3
    hist = jax.random.uniform(jax.random.key(3),
                              (B, cfg.hist_len, cfg.nely, cfg.nelx, 1))
    ref = cronet.forward(cfg, params, lv, hist)
    out = cronet_fused(cfg, params, lv, hist, interpret=True)
    assert out.shape == (B, cfg.p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # legacy unbatched call still returns (p,) and equals slot 0
    one = cronet_fused(cfg, params, lv[0], hist[0], interpret=True)
    assert one.shape == (cfg.p,)
    np.testing.assert_allclose(np.asarray(one), np.asarray(out[0]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sq,sk,hq,hkv,d", [(256, 256, 4, 4, 32),
                                            (512, 512, 8, 2, 16),
                                            (256, 512, 2, 2, 64)])
def test_flash_attention_sweep(sq, sk, hq, hkv, d):
    b = 2
    q = jax.random.normal(jax.random.key(0), (b, sq, hq, d), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.key(1), (b, sk, hkv, d), jnp.float32) * 0.5
    v = jax.random.normal(jax.random.key(2), (b, sk, hkv, d), jnp.float32) * 0.5
    ref = L.attention(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    if sq == sk:
        refc = L.attention(q, k, v, causal=True)
        outc = flash_attention_causal_gqa(q, k, v, block_q=128, block_k=128)
        np.testing.assert_allclose(np.asarray(outc), np.asarray(refc), atol=2e-5)


def test_flash_attention_bf16():
    b, s, h, d = 1, 256, 2, 32
    q = (jax.random.normal(jax.random.key(0), (b, s, h, d)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.key(1), (b, s, h, d)) * 0.5).astype(jnp.bfloat16)
    v = (jax.random.normal(jax.random.key(2), (b, s, h, d)) * 0.5).astype(jnp.bfloat16)
    ref = L.attention(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_slstm_fused_matches_sequential():
    b, s, nh, dh = 2, 64, 2, 8
    d = nh * dh
    wx = jax.random.normal(jax.random.key(0), (b, s, 4 * d), jnp.float32)
    r = jax.random.normal(jax.random.key(1), (nh, dh, 4 * dh), jnp.float32) * 0.3
    out = slstm_fused(wx, r, time_block=16, batch_tile=2)
    # sequential reference
    h = np.zeros((b, d)); c = np.zeros((b, d))
    n = np.zeros((b, d)); m = np.zeros((b, d))
    rs = np.asarray(r)
    ref = []
    for t in range(s):
        rh = np.einsum("bhk,hkj->bhj", h.reshape(b, nh, dh), rs)
        rh = rh.reshape(b, nh, 4, dh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
        pre = np.asarray(wx[:, t]) + rh
        z = np.tanh(pre[:, :d]); i_pre = pre[:, d:2 * d]
        log_f = -np.log1p(np.exp(-pre[:, 2 * d:3 * d]))
        o = 1 / (1 + np.exp(-pre[:, 3 * d:]))
        m_new = np.maximum(log_f + m, i_pre)
        i_g = np.exp(i_pre - m_new); f_g = np.exp(log_f + m - m_new)
        c = f_g * c + i_g * z; n = f_g * n + i_g
        h = o * c / np.maximum(np.abs(n), 1.0)
        ref.append(h.copy()); m = m_new
    np.testing.assert_allclose(np.asarray(out), np.stack(ref, 1), atol=2e-5)


def test_mlstm_chunkwise_matches_sequential():
    """Trained-gate regime (forget bias +2): chunkwise == sequential."""
    b, s, h, dh, L_ = 1, 128, 2, 8, 32
    q = jax.random.normal(jax.random.key(0), (b, s, h, dh)) * 0.5
    k = jax.random.normal(jax.random.key(1), (b, s, h, dh)) * 0.5
    v = jax.random.normal(jax.random.key(2), (b, s, h, dh)) * 0.5
    ip = jax.random.normal(jax.random.key(3), (b, s, h))
    fp = jax.random.normal(jax.random.key(4), (b, s, h)) + 2.0
    C0 = jnp.zeros((b, h, dh, dh)); n0 = jnp.zeros((b, h, dh))
    m0 = jnp.zeros((b, h))
    hs_c, (C_c, n_c, m_c) = REC._mlstm_chunkwise(q, k, v, ip, fp, C0, n0, m0, L_)
    # sequential
    C, n, m = np.array(C0), np.array(n0), np.array(m0)
    hs = []
    for t in range(s):
        qt, kt, vt = np.array(q[:, t]), np.array(k[:, t]), np.array(v[:, t])
        it, ft = np.array(ip[:, t]), np.array(fp[:, t])
        log_f = -np.log1p(np.exp(-ft))
        m_new = np.maximum(log_f + m, it)
        i_g = np.exp(it - m_new); f_g = np.exp(log_f + m - m_new)
        C = f_g[..., None, None] * C + i_g[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f_g[..., None] * n + i_g[..., None] * kt
        num = np.einsum("bhkv,bhk->bhv", C, qt)
        den = np.maximum(np.abs(np.einsum("bhk,bhk->bh", n, qt)), 1.0)
        hs.append(num / den[..., None]); m = m_new
    np.testing.assert_allclose(np.asarray(hs_c), np.stack(hs, 1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(m_c), m, atol=1e-4)


def test_mlstm_block_chunkwise_vs_sequential_path():
    """Full block equality at moderate decay (fp32)."""
    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduce(),
                              dtype="float32")
    params = materialize(M.param_specs(cfg)["superblocks"]["mlstm"],
                         jax.random.key(0))
    p1 = jax.tree.map(lambda a: a[0], params)
    x = jax.random.normal(jax.random.key(1), (2, 128, cfg.d_model)) * 0.3
    out_c, _ = REC.apply_mlstm_block(cfg, p1, x)
    old = REC.MLSTM_CHUNK
    try:
        REC.MLSTM_CHUNK = 1 << 30          # force sequential
        out_s, _ = REC.apply_mlstm_block(cfg, p1, x)
    finally:
        REC.MLSTM_CHUNK = old
    # Random-init gates are an adversarial stiffness regime: sum(log f)
    # ~ -0.7*S puts weights at the fp32 denormal edge, so the two exact-
    # in-exact-arithmetic formulations drift in fp32 (fp64 agreement is
    # 3e-6 — see test_mlstm_chunkwise_matches_sequential for the
    # trained-gate-regime exactness check). Require strong agreement:
    a = np.asarray(out_c, np.float64).ravel()
    b2 = np.asarray(out_s, np.float64).ravel()
    corr = np.corrcoef(a, b2)[0, 1]
    assert corr > 0.999, corr
    assert np.isfinite(a).all()
