"""End-to-end behaviour tests: trainer with checkpoint/resume, serving
engine, hybrid NN-FEA loop, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import materialize
from repro.configs.base import get_config
from repro.models import model as M
from repro.optim import adamw
from repro.train.steps import TrainConfig
from repro.train.trainer import RunConfig, Trainer


def _tc(steps=6):
    return TrainConfig(optimizer=adamw.AdamWConfig(
        lr=1e-3, warmup_steps=1, total_steps=steps))


def test_trainer_end_to_end(tmp_path):
    cfg = get_config("granite-8b").reduce()
    rc = RunConfig(steps=6, batch=2, seq=16, ckpt_dir=str(tmp_path),
                   ckpt_every=3, log_every=2)
    t = Trainer(cfg, _tc(), rc)
    _, _, hist = t.run()
    assert hist[-1]["step"] == 6
    assert all(np.isfinite(h["loss"]) for h in hist)
    # checkpoint landed
    from repro.checkpoint import manager as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 6


def test_trainer_resumes(tmp_path):
    cfg = get_config("granite-8b").reduce()
    rc = RunConfig(steps=4, batch=2, seq=16, ckpt_dir=str(tmp_path),
                   ckpt_every=2, log_every=1)
    t = Trainer(cfg, _tc(4), rc)
    t.run()
    # extend run: trainer must resume from step 4, not restart
    rc2 = RunConfig(steps=6, batch=2, seq=16, ckpt_dir=str(tmp_path),
                    ckpt_every=2, log_every=1)
    t2 = Trainer(cfg, _tc(6), rc2)
    _, _, hist2 = t2.run()
    assert hist2[0]["step"] >= 5   # started past the checkpoint


def test_trainer_with_compression(tmp_path):
    cfg = get_config("granite-8b").reduce()
    tc = TrainConfig(compress_pod_grads=True,
                     optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=5))
    rc = RunConfig(steps=5, batch=2, seq=16, log_every=1)
    _, _, hist = Trainer(cfg, tc, rc).run()
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.5


def test_serving_engine():
    from repro.serve.server import Request, ServingEngine
    cfg = get_config("qwen2.5-32b").reduce()
    params = materialize(M.param_specs(cfg), jax.random.key(0))
    engine = ServingEngine(cfg, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 200, size=5 + i).astype(np.int32),
                    max_new=4) for i in range(3)]
    done = engine.run(reqs)
    assert all(r.done and r.output is not None and len(r.output) == 4
               for r in done)
    stats = engine.throughput_stats(done)
    assert stats["total_new_tokens"] == 12


def test_serving_engine_partial_group_wall_clock_accounting():
    """Regression: the throughput wall clock used to divide every
    request's group latency by the full slot width, so a PARTIAL final
    group (3 requests on a 2-slot engine leaves a group of 1) credited
    its padded slots with work they never did and overstated
    tokens/s. Each group must contribute its dt to the wall exactly
    once — members divide by actual group occupancy."""
    from types import SimpleNamespace

    from repro.serve.server import Request, ServingEngine

    def _reqs(spec):
        out = []
        for group_size, dt in spec:
            for _ in range(group_size):
                r = Request(uid=len(out), prompt=np.zeros(4, np.int32),
                            max_new=4)
                r.done, r.output = True, np.zeros(4, np.int32)
                r.latency_s, r.group_size = dt, group_size
                out.append(r)
        return out

    eng = SimpleNamespace(slots=4)   # throughput_stats only reads slots
    # two full groups + one half-full final group, 1 s each
    reqs = _reqs([(4, 1.0), (4, 1.0), (2, 1.0)])
    stats = ServingEngine.throughput_stats(eng, reqs)
    assert stats["total_new_tokens"] == 40
    # wall = 3 group-seconds exactly; the pre-fix accounting read 2.5 s
    # (the final group contributed 2/4 instead of 2/2) and inflated
    # tokens/s by 20%
    assert stats["tokens_per_s"] == pytest.approx(40 / 3.0)
    # legacy completions without a group stamp fall back to slot width
    legacy = _reqs([(4, 1.0)])
    for r in legacy:
        r.group_size = 0
    assert ServingEngine.throughput_stats(eng, legacy)["tokens_per_s"] \
        == pytest.approx(4 * 4 / 1.0)


def test_hybrid_loop_smoke():
    """12-iteration hybrid NN-FEA loop with an untrained net: must fall
    back to FEA every time and still match the pure-FEA trajectory."""
    import dataclasses

    from repro.configs.cronet import get_cronet_config
    from repro.core import cronet
    from repro.fea import hybrid
    cfg = dataclasses.replace(get_cronet_config("small"), nelx=12, nely=4)
    params = materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
    res = hybrid.run_hybrid(cfg, params, u_scale=100.0, n_iter=12,
                            precision="fp32")
    assert res.fea_invocations >= 10      # untrained net is rejected
    assert res.solution_accuracy > 95.0   # therefore tracks pure FEA


def test_hlo_analyzer_scan_exact():
    from repro.launch.hlo_analysis import analyze
    L = 5

    def f(ws, x):
        def body(x, w):
            return jnp.dot(x, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    compiled = jax.jit(f).lower(ws, x).compile()
    costs = analyze(compiled.as_text())
    assert costs.flops == 2 * L * 8 * 64 * 64


def test_input_specs_cover_all_cells():
    """Every applicable (arch x shape) produces abstract inputs with no
    allocation (the dry-run's contract)."""
    from repro.configs.all import ASSIGNED
    from repro.configs.base import applicable_shapes
    from repro.launch.specs import input_specs
    n = 0
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            specs = input_specs(cfg, shape)
            assert all(isinstance(l, jax.ShapeDtypeStruct)
                       for l in jax.tree.leaves(specs))
            n += 1
    assert n == 31   # 40 assigned cells minus 9 documented skips
