"""Hypothesis compatibility shim: re-export the real library when it is
installed; otherwise degrade @given property tests into deterministic
parametrized sweeps (boundary values first, then seeded random samples) so
the tier-1 suite collects and runs in minimal environments.

Usage in test modules (tests/ is on sys.path during collection):

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    N_EXAMPLES = 10

    class _Strategy:
        def __init__(self, lo, hi, sampler):
            self.lo, self.hi = lo, hi
            self._sampler = sampler

        def example_at(self, i, rng):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return self._sampler(rng)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(min_value, max_value,
                             lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(min_value, max_value,
                             lambda rng: rng.randint(min_value, max_value))

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strategies):
        """Run the wrapped property N_EXAMPLES times: both bounds first,
        then seeded random draws. The wrapper takes no arguments so pytest
        does not mistake the property's parameters for fixtures."""
        def deco(fn):
            def wrapper():
                rng = random.Random(0xC0FFEE)
                for i in range(N_EXAMPLES):
                    fn(*[s.example_at(i, rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
