"""Multi-process engine-worker tests (serve/workers.py).

Three layers:
  * device-free units: the length-prefixed pickle framing (round-trip,
    torn-frame detection) and the ``RemoteEngine`` crash-split logic
    against a stub pool/handle — admitted in-flight work fails typed
    ``WorkerLost``, never-admitted work requeues in ORIGINAL submission
    order (priority + absolute deadline ride along, so EDF rank is
    preserved);
  * real processes, deterministic crash: a gateway serving through one
    worker, ``kill -9`` mid-tick — the admitted request's future fails
    with ``WorkerLost`` (carrying the dead worker's id), the queued one
    transparently completes on the respawned worker, the ``worker-*``
    FleetEvents narrate the loss/respawn/reassign/requeue, and the
    registry lease survives because the bucket proxy never left the
    gateway;
  * property-style interleaving sweep (slow tier, mirroring
    tests/test_flywheel.py's): random rounds of traffic + worker kills
    through a registry-backed two-worker gateway — every future
    resolves (density or typed ``WorkerLost``), zero drops, zero
    mis-tags, leases balance after shutdown.

Worker processes are spawned (never forked — the child must not inherit
the parent's XLA state), so each spawn re-imports jax: tests here keep
worker counts and respawn rounds small on purpose.
"""
import collections
import dataclasses
import multiprocessing
import os
import random
import signal
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest
from test_gateway import wait_until

from repro.serve import (TopoGateway, TopoRequest, WorkerLost)
from repro.serve.types import TopoFuture
from repro.serve.workers import (RemoteEngine, _recv_msg, _send_msg)

U_SCALE = 50.0


# ------------------------------------------------------------- framing


def test_framing_roundtrip_and_torn_frame_detection():
    a, b = multiprocessing.get_context("spawn").Pipe(duplex=True)
    lock = threading.Lock()
    msg = {"op": "submit", "payload": np.arange(6).reshape(2, 3),
           "nested": {"deadline": 12.5}}
    _send_msg(a, lock, msg)
    got = _recv_msg(b)
    assert got["op"] == "submit"
    np.testing.assert_array_equal(got["payload"], msg["payload"])

    # a frame whose prefix disagrees with its body is a torn write
    # (worker killed mid-send): typed error, not a pickle explosion
    import struct
    a.send_bytes(struct.pack("!I", 999) + b"\x80\x04short")
    with pytest.raises(ValueError, match="torn frame"):
        _recv_msg(b)
    a.close()
    with pytest.raises((EOFError, OSError)):
        _recv_msg(b)
    b.close()


# ----------------------------------- crash-split units (stub pool/handle)


class _StubHandle:
    """Records submit RPCs instead of crossing a pipe."""

    def __init__(self, worker_id=7, fail=False):
        self.worker_id = worker_id
        self.fail = fail
        self.submitted = []          # uids, arrival order

    def call(self, op, timeout=None, **fields):
        if self.fail:
            raise WorkerLost("stub worker down", worker_id=self.worker_id)
        if op == "submit":
            self.submitted.append(fields["req"].uid)
        return True


def _stub_proxy(handle):
    pool = SimpleNamespace(rpc_timeout_s=5.0, registry_root=None,
                           _note_completion=lambda *a, **k: None,
                           _forget_engine=lambda p: None)
    cfg = SimpleNamespace(nelx=12, nely=4)
    return RemoteEngine(pool, handle, engine_id=0, mesh=(12, 4), cfg=cfg,
                        spec={"cfg": cfg}, model_tag="m", slots=2)


def _preq(uid, priority=0, deadline_s=None):
    req = TopoRequest(uid=uid, problem=SimpleNamespace(nelx=12, nely=4),
                      n_iter=4, deadline_s=deadline_s, priority=priority)
    return req


def test_crash_split_fails_admitted_typed_and_requeues_in_edf_order():
    h0 = _StubHandle(worker_id=0)
    eng = _stub_proxy(h0)
    futs = [eng.submit(_preq(i, priority=i % 2, deadline_s=30.0 + i))
            for i in range(5)]
    assert h0.submitted == [0, 1, 2, 3, 4]
    # uids 0 and 2 reached a tick on the (about to die) worker
    eng._on_admitted(0, time.monotonic())
    eng._on_admitted(2, time.monotonic())

    admitted, queued = eng._split_pending()
    assert [r.uid for r, _ in admitted] == [0, 2]
    assert [r.uid for r, _ in queued] == [1, 3, 4]   # original order
    eng._fail_admitted(admitted, worker_id=0, reason="kill -9")
    for f in (futs[0], futs[2]):
        exc = f.exception()
        assert isinstance(exc, WorkerLost) and exc.worker_id == 0

    h1 = _StubHandle(worker_id=1)
    assert eng._rebind(h1, queued) == 3
    # resubmitted on the replacement in ORIGINAL submission order, on
    # the ORIGINAL request objects — priority and the absolute
    # monotonic deadline ride along, so the engine-side EDF scheduler
    # reconstructs the exact rank the dead worker saw
    assert h1.submitted == [1, 3, 4]
    assert eng.inflight == 3
    with eng._sched.cond:
        pend = [ent[0] for ent in eng._pending.values()]
    assert [r.priority for r in pend] == [1, 1, 0]
    assert all(r.deadline is not None for r in pend)
    for uid in (1, 3, 4):
        assert not futs[uid].done()


def test_rebind_onto_dead_replacement_fails_every_future_typed():
    eng = _stub_proxy(_StubHandle(worker_id=0))
    futs = [eng.submit(_preq(i)) for i in range(3)]
    _, queued = eng._split_pending()
    eng._rebind(_StubHandle(worker_id=1, fail=True), queued)
    for f in futs:
        assert isinstance(f.exception(), WorkerLost)
    assert eng.inflight == 0


# --------------------------------------------- real processes: kill -9


@pytest.fixture(scope="module")
def trained():
    import jax

    from repro.common import materialize
    from repro.configs.cronet import get_cronet_config
    from repro.core import cronet

    cfg = dataclasses.replace(get_cronet_config("small"),
                              nelx=12, nely=4, hist_len=3)
    params = materialize(cronet.param_specs(
        dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
    return cfg, params


def _problems(n, nelx=12, nely=4):
    from repro.fea import fea2d
    return [fea2d.point_load_problem(nelx, nely,
                                     load_node=(i % (nelx - 1), 0),
                                     load=(0.0, -1.0 - 0.1 * i))
            for i in range(n)]


def test_kill9_mid_tick_fails_admitted_typed_and_requeues_rest(trained):
    """THE crash contract: kill -9 a worker while one request is in a
    tick and another is queued behind it. The admitted one fails with
    a typed ``WorkerLost`` naming the dead worker; the queued one is
    requeued onto the respawned worker and completes; the fleet-event
    log narrates every transition; zero requests are dropped."""
    cfg, params = trained
    probs = _problems(4)
    gw = TopoGateway(cfg, params, U_SCALE, slots=2, max_pending=16,
                     workers=1,
                     worker_pool_kwargs={"heartbeat_s": 0.5})
    try:
        # uids 0-1 run long (they will be mid-tick at the kill); uids
        # 2-3 queue behind the two slots and never reach a tick
        futs = [gw.submit(TopoRequest(uid=i, problem=p,
                                      n_iter=200 if i < 2 else 4))
                for i, p in enumerate(probs)]
        assert wait_until(
            lambda: gw.engines.get((12, 4)) is not None, timeout=120)
        proxy = gw.engines[(12, 4)]
        assert isinstance(proxy, RemoteEngine)
        # wait until uids 0-1 are ADMITTED to ticks (the worker-side
        # monitor reported them) while 2-3 sit queued behind the slots
        def _admitted(uid):
            with proxy._sched.cond:
                ent = proxy._pending.get(uid)
                return ent is not None and ent[2]
        assert wait_until(lambda: _admitted(0) and _admitted(1),
                          timeout=120)
        victim_pid = gw._pool._workers[0].proc.pid
        victim_id = gw._pool._workers[0].worker_id
        os.kill(victim_pid, signal.SIGKILL)

        results = {}
        for i, f in enumerate(futs):
            try:
                results[i] = f.result(timeout=300)
            except WorkerLost as exc:
                results[i] = exc
        # uids 0-1 were mid-tick: typed loss carrying the dead
        # worker's id
        for i in (0, 1):
            assert isinstance(results[i], WorkerLost)
            assert results[i].worker_id == victim_id
        # uids 2-3 never reached a tick on the dead worker: they
        # completed on the respawn, densities intact, relabelled
        for i in (2, 3):
            assert not isinstance(results[i], BaseException)
            assert results[i].done and results[i].density is not None
            assert results[i].worker_id is not None
            assert results[i].worker_id != victim_id
        kinds = [e.kind for e in gw.fleet_events()]
        for k in ("worker-spawn", "worker-lost", "worker-reassign",
                  "worker-requeue"):
            assert k in kinds, f"missing {k} in {kinds}"
        assert gw._pool.stats()["restarts"] >= 1
    finally:
        gw.shutdown()


@pytest.mark.slow
def test_worker_interleaving_sweep_no_drops_no_mistags(trained, tmp_path):
    """Property-style sweep (the flywheel suite's idiom): random rounds
    of traffic and worker kills through a registry-backed two-worker
    gateway. Invariants after every round: every future resolves with
    a density or a typed ``WorkerLost``; completions carry the tag they
    were routed under and a worker id; nothing is dropped. After
    shutdown: leases balance to zero."""
    from repro.serve import ModelRegistry

    cfg, params = trained
    reg = ModelRegistry(str(tmp_path))
    reg.register(params, cfg, U_SCALE, tag="prod")
    gw = TopoGateway.from_registry(
        reg, tag="prod", slots=2, max_pending=64, workers=2,
        worker_pool_kwargs={"heartbeat_s": 0.5})
    rng = random.Random(20260808)
    probs = _problems(6)
    uid = 0
    try:
        for rnd in range(4):
            futs = []
            for _ in range(rng.randint(3, 6)):
                futs.append(gw.submit(TopoRequest(
                    uid=uid, problem=probs[uid % len(probs)],
                    n_iter=rng.randint(3, 8),
                    deadline_s=600.0 if rng.random() < 0.5 else None,
                    priority=rng.randint(0, 2))))
                uid += 1
            if rnd in (1, 2):       # two kill rounds out of four
                live = gw._pool.live_workers()
                victim = rng.choice(live)
                os.kill(victim.proc.pid, signal.SIGKILL)
            completed = lost = 0
            for f in futs:
                try:
                    r = f.result(timeout=300)
                    assert r.density is not None
                    assert r.model_tag == "prod"
                    assert r.routed_tag == "prod"
                    assert r.worker_id is not None
                    completed += 1
                except WorkerLost:
                    lost += 1
            assert completed + lost == len(futs)
        assert gw._pool.stats()["restarts"] >= 1
    finally:
        gw.shutdown()
    assert reg.leased() == {}
