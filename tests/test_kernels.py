"""Per-kernel shape/dtype sweeps + hypothesis properties vs ref.py oracles
(every Pallas kernel validated in interpret mode, per the deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import conv as kconv
from repro.kernels import gemm as kgemm
from repro.kernels import pool as kpool
from repro.kernels import ref as kref
from repro.kernels import silu as ksilu

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _assert_close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10)


# ---------------------------------------------------------------- GEMM
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (33, 70, 9), (128, 128, 128),
                                   (1, 4800, 40), (40, 40, 2560)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", [None, "silu", "tanh"])
def test_gemm_sweep(m, k, n, dtype, act):
    kx = jax.random.key(m * 1000 + k)
    x = (jax.random.normal(kx, (m, k), jnp.float32) * 0.3).astype(dtype)
    w = (jax.random.normal(jax.random.key(n), (k, n), jnp.float32) * 0.3).astype(dtype)
    _assert_close(kgemm.gemm(x, w, activation=act), kref.gemm(x, w, act), dtype)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 60), st.integers(1, 40))
def test_gemm_property_arbitrary_mkn(m, k, n):
    """Paper claim: full M/K/N parameterization (no GAMA fixed dims)."""
    x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
    _assert_close(kgemm.gemm(x, w), kref.gemm(x, w), jnp.float32)


# ---------------------------------------------------------------- Conv
@pytest.mark.parametrize("b,h,w,cin,cout", [(1, 10, 30, 1, 16), (10, 20, 30, 16, 32),
                                            (2, 7, 9, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_sweep(b, h, w, cin, cout, dtype):
    x = (jax.random.normal(jax.random.key(0), (b, h, w, cin), jnp.float32) * 0.5).astype(dtype)
    wt = (jax.random.normal(jax.random.key(1), (3, 3, cin, cout), jnp.float32) * 0.3).astype(dtype)
    _assert_close(kconv.conv2d(x, wt), kref.conv2d_same(x, wt), dtype)
    _assert_close(kconv.conv2d(x, wt, fuse_silu=True),
                  jax.nn.silu(kref.conv2d_same(x, wt).astype(jnp.float32)).astype(dtype),
                  dtype)


@pytest.mark.parametrize("kd,depth_padding", [(2, "causal_same"), (1, "same")])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv3d_sweep(kd, depth_padding, dtype):
    x = (jax.random.normal(jax.random.key(0), (2, 4, 11, 21, 3), jnp.float32) * 0.5).astype(dtype)
    wt = (jax.random.normal(jax.random.key(1), (kd, 3, 3, 3, 8), jnp.float32) * 0.3).astype(dtype)
    _assert_close(kconv.conv3d(x, wt, depth_padding=depth_padding),
                  kref.conv3d(x, wt, depth_padding), dtype)


# ---------------------------------------------------------------- Pools
@pytest.mark.parametrize("h,w", [(20, 30), (10, 15), (7, 9)])
def test_maxpool2d(h, w):
    x = jax.random.normal(jax.random.key(2), (3, h, w, 8), jnp.float32)
    _assert_close(kpool.maxpool2d(x), kref.maxpool2d(x), jnp.float32)


@pytest.mark.parametrize("hw,out", [((10, 15), (1, 1)), ((21, 31), (5, 5)),
                                    ((7, 9), (3, 4))])
def test_aap2d(hw, out):
    x = jax.random.normal(jax.random.key(3), (2, *hw, 6), jnp.float32)
    _assert_close(kpool.adaptive_avg_pool2d(x, out),
                  kref.adaptive_avg_pool2d(x, out), jnp.float32)


@pytest.mark.parametrize("dhw,out", [((4, 21, 31), (3, 5, 5)),
                                     ((5, 8, 9), (2, 3, 3))])
def test_aap3d(dhw, out):
    x = jax.random.normal(jax.random.key(4), (2, *dhw, 6), jnp.float32)
    _assert_close(kpool.adaptive_avg_pool3d(x, out),
                  kref.adaptive_avg_pool3d(x, out), jnp.float32)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(2, 16), st.integers(1, 4), st.integers(1, 4))
def test_aap2d_property_windows_cover(h, w, oh, ow):
    """AAP property: output of constant input is that constant (windows
    tile the input exactly — the paper's 'fixed output size regardless of
    input dimensions' contract)."""
    x = jnp.full((1, h, w, 2), 3.25, jnp.float32)
    out = kpool.adaptive_avg_pool2d(x, (min(oh, h), min(ow, w)))
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-6)


# ---------------------------------------------------------------- SiLU
def test_silu_lut_matches_oracle():
    x = jnp.linspace(-12, 12, 1001, dtype=jnp.float32)
    _assert_close(ksilu.silu_lut(x), kref.silu_lut(x), jnp.float32)


def test_silu_lut_accuracy_vs_exact():
    """LUT error must be below bf16 resolution in the active range (the
    paper's justification for LUT at bf16 inference)."""
    x = jnp.linspace(-8, 8, 4001, dtype=jnp.float32)
    err = jnp.max(jnp.abs(ksilu.silu_lut(x) - jax.nn.silu(x)))
    assert float(err) < 0.05


def test_silu_exact_kernel():
    x = jax.random.normal(jax.random.key(5), (513,), jnp.float32) * 3
    _assert_close(ksilu.silu_exact(x), jax.nn.silu(x), jnp.float32)
