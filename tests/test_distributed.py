"""Distributed-path integration tests. Each runs in a SUBPROCESS with
--xla_force_host_platform_device_count so the main pytest process keeps its
single real device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 4, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2500:]
    return proc.stdout


PREAMBLE = """
import jax, jax.numpy as jnp
from jax.sharding import AxisType
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
from repro.common import materialize
from repro.configs.base import get_config
from repro.models import model as M
from repro.parallel.sharding import spec_tree_to_shardings
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Same seed, same batch: 2x2-mesh sharded loss == unsharded loss."""
    out = _run(PREAMBLE + """
from repro.data.pipeline import TokenPipeline
from repro.train.steps import TrainConfig, make_train_step
from repro.optim import adamw
import dataclasses
cfg = dataclasses.replace(get_config('granite-8b').reduce(), dtype='float32')
specs = M.param_specs(cfg)
params = materialize(specs, jax.random.key(0))
tc = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=5))
opt = adamw.init_state(tc.optimizer, params)
batch = {k: jnp.asarray(v) for k, v in TokenPipeline(cfg, 4, 16).next_batch().items()}
# single-device reference
step0 = jax.jit(make_train_step(cfg, tc, None))
_, _, m0 = step0(params, opt, batch)
# sharded
pshard = spec_tree_to_shardings(specs, mesh)
with mesh:
    step1 = jax.jit(make_train_step(cfg, tc, mesh), in_shardings=(pshard, None, None))
    _, _, m1 = step1(params, opt, batch)
print("LOSS0", float(m0["loss"]))
print("LOSS1", float(m1["loss"]))
assert abs(float(m0["loss"]) - float(m1["loss"])) < 2e-4
""")
    assert "LOSS0" in out


@pytest.mark.slow
def test_moe_ep_all_to_all_correct():
    out = _run(PREAMBLE + """
import dataclasses
from repro.models import moe as MOE
cfg = dataclasses.replace(get_config('deepseek-v3-671b').reduce(),
                          dtype='float32', num_experts=8, moe_capacity_factor=16.0)
specs = M.param_specs(cfg)['moe_blocks']['moe']
params = materialize(specs, jax.random.key(0))
p1 = jax.tree.map(lambda a: a[0], params)
x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
ref, _ = MOE.apply_moe(cfg, p1, x, None)
with mesh:
    out, _ = jax.jit(lambda p, x: MOE.apply_moe(cfg, p, x, mesh))(p1, x)
diff = float(jnp.max(jnp.abs(ref - out)))
print("DIFF", diff)
assert diff < 1e-4
""")
    assert "DIFF" in out


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint written unsharded restores onto a 2x2 mesh (elastic)."""
    out = _run(PREAMBLE + f"""
from repro.checkpoint import manager as ckpt
cfg = get_config('granite-8b').reduce()
specs = M.param_specs(cfg)
params = materialize(specs, jax.random.key(0))
ckpt.save({str(tmp_path)!r}, 1, {{"params": params}})
shard = {{"params": spec_tree_to_shardings(specs, mesh)}}
restored, _ = ckpt.restore({str(tmp_path)!r}, {{"params": params}}, shardings=shard)
leaf = jax.tree.leaves(restored["params"])[0]
print("SHARDED", leaf.sharding)
import numpy as np
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell end-to-end (reduced device count for speed is
    NOT possible — the production mesh is fixed — so this is the true
    16x16 compile, proving the deliverable in CI)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-3b-a800m", "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=420, cwd=ROOT)
    assert proc.returncode == 0, proc.stderr[-2500:]
    d = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert d["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert d["flops_per_device"] > 0
