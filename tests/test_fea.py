"""FEA/SIMP baseline properties (unit + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.fea import fea2d, simp


@pytest.fixture(scope="module")
def prob():
    return fea2d.mbb_problem(12, 6)


def test_stiffness_spd(prob):
    """u^T K u > 0 for nonzero free u (K SPD on free dofs)."""
    rng = np.random.default_rng(0)
    for _ in range(5):
        u = jnp.asarray(rng.standard_normal(prob.f.shape[0])) * prob.free_mask
        x = jnp.full((prob.nely, prob.nelx), 0.5)
        e = float(jnp.vdot(u, fea2d.stiffness_apply(prob, x, u)))
        assert e > 0


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 1.0), st.floats(0.1, 1.0))
def test_stiffness_linearity(a, b):
    """K(x) (a u1 + b u2) == a K u1 + b K u2."""
    prob = fea2d.mbb_problem(8, 4)
    rng = np.random.default_rng(1)
    u1 = jnp.asarray(rng.standard_normal(prob.f.shape[0]))
    u2 = jnp.asarray(rng.standard_normal(prob.f.shape[0]))
    x = jnp.full((prob.nely, prob.nelx), 0.7)
    lhs = fea2d.stiffness_apply(prob, x, a * u1 + b * u2)
    rhs = a * fea2d.stiffness_apply(prob, x, u1) + b * fea2d.stiffness_apply(prob, x, u2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-6)


def test_cg_solves(prob):
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, it = fea2d.solve(prob, x)
    r = prob.f * prob.free_mask - fea2d.stiffness_apply(prob, x, u)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(prob.f))
    assert rel < 5e-4      # fp32 CG floor on ill-conditioned SIMP stiffness
    assert int(it) < 2000


def test_fixed_dofs_zero(prob):
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, _ = fea2d.solve(prob, x)
    fixed = np.where(np.asarray(prob.free_mask) == 0)[0]
    np.testing.assert_allclose(np.asarray(u)[fixed], 0.0)


def test_denser_is_stiffer(prob):
    """More material => lower compliance (monotonicity)."""
    u1, _ = fea2d.solve(prob, jnp.full((prob.nely, prob.nelx), 0.3))
    c1, _ = fea2d.compliance_and_sens(prob, jnp.full((prob.nely, prob.nelx), 0.3), u1)
    u2, _ = fea2d.solve(prob, jnp.full((prob.nely, prob.nelx), 0.9))
    c2, _ = fea2d.compliance_and_sens(prob, jnp.full((prob.nely, prob.nelx), 0.9), u2)
    assert float(c2) < float(c1)


def test_sensitivities_negative(prob):
    """dC/dx <= 0 everywhere: adding material never hurts compliance."""
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, _ = fea2d.solve(prob, x)
    _, dc = fea2d.compliance_and_sens(prob, x, u)
    assert float(jnp.max(dc)) <= 1e-9


def test_simp_improves_and_respects_volume(prob):
    state, hist = simp.run_simp(prob, n_iter=8)
    assert hist["c"][-1] < hist["c"][0]
    assert abs(float(jnp.mean(state.x)) - prob.volfrac) < 0.01
    assert float(state.x.min()) >= 0.001 and float(state.x.max()) <= 1.0


def test_oc_update_volume_projection():
    x = jnp.full((6, 12), 0.5)
    dc = -jnp.abs(jax.random.normal(jax.random.key(0), (6, 12)))
    dv = jnp.ones_like(x) / x.size
    xn = simp.oc_update(x, dc, dv, 0.5)
    assert abs(float(jnp.mean(xn)) - 0.5) < 0.02


def test_pad_problem_passive_border_and_crop_roundtrip():
    p = fea2d.point_load_problem(10, 4, load_node=(3, 0), load=(0.0, -1.2))
    pp = fea2d.pad_problem(p, 12, 6)
    assert (pp.nelx, pp.nely) == (12, 6)
    m = np.asarray(pp.elem_mask)
    assert m.shape == (6, 12) and m.sum() == 10 * 4
    # mask follows the density-layout flat convention (el = ex*nely + ey)
    g = m.reshape(12, 6)
    assert g[:10, :4].all() and not g[10:, :].any() and not g[:, 4:].any()
    # crop_density inverts the embedding on an arbitrary design field
    rng = np.random.default_rng(0)
    x_orig = rng.random((4, 10)).astype(np.float32)
    buf = np.zeros((12, 6), np.float32)
    buf[:10, :4] = x_orig.reshape(10, 4)
    np.testing.assert_array_equal(
        fea2d.crop_density(buf.reshape(6, 12), 10, 4), x_orig)
    # exact fit: same problem back, just moved onto the masked family
    same = fea2d.pad_problem(p, 10, 4)
    assert np.asarray(same.elem_mask).all()
    np.testing.assert_array_equal(np.asarray(same.f), np.asarray(p.f))
    with pytest.raises(ValueError, match="smaller"):
        fea2d.pad_problem(p, 8, 4)
    with pytest.raises(ValueError, match="smaller"):
        fea2d.crop_density(buf.reshape(6, 12), 14, 4)


def test_padded_solve_matches_original_physics():
    """The passive border is inert: solving the padded problem at the
    embedded density gives the original compliance (padded elements have
    zero stiffness and their dofs are fixed, so the active subsystem is
    the original one)."""
    p = fea2d.point_load_problem(10, 4, load_node=(3, 0), load=(0.0, -1.2))
    pp = fea2d.pad_problem(p, 12, 6)
    xo = jnp.full((4, 10), p.volfrac)
    xp = jnp.asarray(np.asarray(pp.elem_mask) * p.volfrac)
    uo, _ = fea2d.solve(p, xo)
    up, _ = fea2d.solve(pp, xp)
    co, dco = fea2d.compliance_and_sens(p, xo, uo)
    cp, dcp = fea2d.compliance_and_sens(pp, xp, up)
    assert np.isclose(float(co), float(cp), rtol=1e-4)
    # sensitivities vanish identically on the passive border
    assert not np.asarray(dcp)[np.asarray(pp.elem_mask) == 0.0].any()


def test_masked_oc_update_freezes_passive_and_scales_volume():
    """With a mask the OC update keeps passive densities at exactly 0 and
    takes the volume constraint over ACTIVE elements only, so volfrac
    keeps its meaning on the original (pre-padding) mesh."""
    p = fea2d.point_load_problem(10, 4)
    mask = fea2d.pad_problem(p, 12, 6).elem_mask
    x = jnp.asarray(np.asarray(mask) * 0.5)
    dc = -jnp.abs(jax.random.normal(jax.random.key(1), (6, 12))) * mask
    dv = jnp.ones_like(x) / x.size
    xn = simp.oc_update(x, dc, dv, 0.5, mask=mask)
    m = np.asarray(mask)
    assert not np.asarray(xn)[m == 0.0].any()
    active_mean = float(np.asarray(xn)[m == 1.0].mean())
    assert abs(active_mean - 0.5) < 0.02


def test_padded_oc_volume_matches_dedicated():
    """Regression: the hybrid step used to hand ``oc_update_b`` the
    padded mesh's uniform volume gradient 1/(nelx*nely) even when
    ``bp.elem_mask`` marked most of it passive — the ACTIVE-element
    volume constraint has per-slot gradient 1/active_count under
    shape-class padding. After a step the active-region volume of a
    padded slot must equal the dedicated (unpadded) run's volume, and
    no NaNs may leak from the passive border (a masked dv of the form
    mask/active would put 0/0 on passive elements)."""
    from repro.fea import hybrid
    from repro.configs.cronet import get_cronet_config
    from repro.common import materialize
    from repro.core import cronet
    import dataclasses

    p = fea2d.point_load_problem(10, 4, load_node=(3, 0), load=(0.0, -1.2))
    pp = fea2d.pad_problem(p, 12, 6)

    def run(cfg_dims, probs):
        cfg = dataclasses.replace(get_cronet_config("small"),
                                  nelx=cfg_dims[0], nely=cfg_dims[1],
                                  hist_len=3)
        params = materialize(cronet.param_specs(
            dataclasses.replace(cfg, dtype="float32")), jax.random.key(0))
        bp = fea2d.stack_problems(probs)
        step = hybrid.make_hybrid_step(cfg, 50.0, precision="fp32")
        state = hybrid.init_state(cfg, bp)
        load_vol = fea2d.load_volume_b(bp)
        cparams = hybrid.cast_params(params, "fp32")
        for _ in range(3):
            state = step(cparams, bp, load_vol, state)
        return np.asarray(state.x)

    x_ded = run((10, 4), [p, p])
    x_pad = run((12, 6), [pp, pp])
    assert not np.isnan(x_pad).any(), "NaNs leaked from the passive border"
    m = np.asarray(pp.elem_mask)
    # passive border stays exactly empty
    assert not x_pad[0][m == 0.0].any()
    vol_ded = x_ded[0].mean()
    vol_pad = x_pad[0][m == 1.0].mean()
    assert abs(vol_pad - vol_ded) < 1e-3, (
        f"padded active volume {vol_pad:.6f} != dedicated {vol_ded:.6f}")
    # both runs actually project onto the volume constraint
    assert abs(vol_ded - p.volfrac) < 0.02


def test_load_volume_layout(prob):
    vol = fea2d.load_volume(prob)
    assert vol.shape == (4, prob.nely + 1, prob.nelx + 1, 1)
    # Fy at node (0,0) carries the unit load
    assert float(vol[1, 0, 0, 0]) == -1.0
    # left edge x-support flags set
    assert float(vol[2, :, 0, 0].sum()) == prob.nely + 1
