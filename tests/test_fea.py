"""FEA/SIMP baseline properties (unit + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.fea import fea2d, simp


@pytest.fixture(scope="module")
def prob():
    return fea2d.mbb_problem(12, 6)


def test_stiffness_spd(prob):
    """u^T K u > 0 for nonzero free u (K SPD on free dofs)."""
    rng = np.random.default_rng(0)
    for _ in range(5):
        u = jnp.asarray(rng.standard_normal(prob.f.shape[0])) * prob.free_mask
        x = jnp.full((prob.nely, prob.nelx), 0.5)
        e = float(jnp.vdot(u, fea2d.stiffness_apply(prob, x, u)))
        assert e > 0


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 1.0), st.floats(0.1, 1.0))
def test_stiffness_linearity(a, b):
    """K(x) (a u1 + b u2) == a K u1 + b K u2."""
    prob = fea2d.mbb_problem(8, 4)
    rng = np.random.default_rng(1)
    u1 = jnp.asarray(rng.standard_normal(prob.f.shape[0]))
    u2 = jnp.asarray(rng.standard_normal(prob.f.shape[0]))
    x = jnp.full((prob.nely, prob.nelx), 0.7)
    lhs = fea2d.stiffness_apply(prob, x, a * u1 + b * u2)
    rhs = a * fea2d.stiffness_apply(prob, x, u1) + b * fea2d.stiffness_apply(prob, x, u2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-6)


def test_cg_solves(prob):
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, it = fea2d.solve(prob, x)
    r = prob.f * prob.free_mask - fea2d.stiffness_apply(prob, x, u)
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(prob.f))
    assert rel < 5e-4      # fp32 CG floor on ill-conditioned SIMP stiffness
    assert int(it) < 2000


def test_fixed_dofs_zero(prob):
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, _ = fea2d.solve(prob, x)
    fixed = np.where(np.asarray(prob.free_mask) == 0)[0]
    np.testing.assert_allclose(np.asarray(u)[fixed], 0.0)


def test_denser_is_stiffer(prob):
    """More material => lower compliance (monotonicity)."""
    u1, _ = fea2d.solve(prob, jnp.full((prob.nely, prob.nelx), 0.3))
    c1, _ = fea2d.compliance_and_sens(prob, jnp.full((prob.nely, prob.nelx), 0.3), u1)
    u2, _ = fea2d.solve(prob, jnp.full((prob.nely, prob.nelx), 0.9))
    c2, _ = fea2d.compliance_and_sens(prob, jnp.full((prob.nely, prob.nelx), 0.9), u2)
    assert float(c2) < float(c1)


def test_sensitivities_negative(prob):
    """dC/dx <= 0 everywhere: adding material never hurts compliance."""
    x = jnp.full((prob.nely, prob.nelx), 0.5)
    u, _ = fea2d.solve(prob, x)
    _, dc = fea2d.compliance_and_sens(prob, x, u)
    assert float(jnp.max(dc)) <= 1e-9


def test_simp_improves_and_respects_volume(prob):
    state, hist = simp.run_simp(prob, n_iter=8)
    assert hist["c"][-1] < hist["c"][0]
    assert abs(float(jnp.mean(state.x)) - prob.volfrac) < 0.01
    assert float(state.x.min()) >= 0.001 and float(state.x.max()) <= 1.0


def test_oc_update_volume_projection():
    x = jnp.full((6, 12), 0.5)
    dc = -jnp.abs(jax.random.normal(jax.random.key(0), (6, 12)))
    dv = jnp.ones_like(x) / x.size
    xn = simp.oc_update(x, dc, dv, 0.5)
    assert abs(float(jnp.mean(xn)) - 0.5) < 0.02


def test_load_volume_layout(prob):
    vol = fea2d.load_volume(prob)
    assert vol.shape == (4, prob.nely + 1, prob.nelx + 1, 1)
    # Fy at node (0,0) carries the unit load
    assert float(vol[1, 0, 0, 0]) == -1.0
    # left edge x-support flags set
    assert float(vol[2, :, 0, 0].sum()) == prob.nely + 1
